//! `ptgs serve` — scheduling as a service: a persistent daemon that
//! runs the fused 72-config sweep per request over plain HTTP/1.1
//! (in-crate framing, [`http`]; this environment vendors no web stack).
//!
//! Architecture (pure `std::thread`, no async runtime):
//!
//! * an **acceptor** thread owns the listener and spawns one detached
//!   connection thread per client (keep-alive, bounded read timeout);
//! * connection threads parse requests and push jobs onto a **bounded
//!   queue** ([`queue::BoundedQueue`]) — a full queue sheds load with
//!   HTTP 429 instead of buffering unboundedly, and every request
//!   carries a deadline (default [`ServeOptions::default_timeout`],
//!   per-request `timeout_ms`) answered with 408 when missed;
//! * a fixed pool of **worker** threads each owns one warm
//!   [`SchedulerWorkspace`] for its whole lifetime, so after a couple
//!   of warm-up requests repeat traffic runs allocation-free (the PR 4
//!   `buffer_allocations()` counter test extends across requests in
//!   `tests/integration_ctx.rs`); a panicking job is contained
//!   (`catch_unwind`, same policy as [`crate::coordinator`]) and fails
//!   only its own request with a 500 — the daemon keeps serving;
//! * a **response cache** ([`cache::ResponseCache`]) keyed by FNV-1a
//!   content hash of the raw body lets byte-identical resubmissions
//!   skip parsing, context warm-up, and the sweep entirely.
//!
//! Endpoints: `POST /schedule` (instance in, per-config makespans +
//! dedup equivalence classes out), `GET /stats` (queue depth, cache
//! hit rate, fused-engine counters, latency percentiles),
//! `GET /healthz`, and `POST /shutdown` — the clean-shutdown control
//! path (a pure-std process cannot trap SIGTERM; orchestrators should
//! POST /shutdown and then wait for exit).
//!
//! Overload robustness (see ARCHITECTURE.md §Service robustness):
//!
//! * **Cooperative cancellation** — every job carries a
//!   [`crate::scheduler::CancelToken`] chained off the server's
//!   shutdown token with the request deadline attached, and the sweep
//!   polls it once per scheduling iteration. A request that expires
//!   *mid-sweep* aborts at the next iteration (408), its worker's warm
//!   workspace is returned to clean via pure pool-recycling, and the
//!   next request on that worker allocates nothing new.
//! * **Graceful degradation** — when the queue backlog crosses
//!   [`ServeOptions::degrade_threshold`], new requests downgrade to a
//!   portfolio fast path ([`SchedulerConfig::portfolio`]): five strong
//!   configs instead of the full sweep, answered with `degraded: true`
//!   and never cached. The shed ladder is full sweep → portfolio →
//!   429 + `Retry-After`.
//! * **Bounded drain** — shutdown stops accepting, drains queued work,
//!   and gives in-flight sweeps [`ServeOptions::drain_grace`] before a
//!   watchdog cancels them; live connections are then waited out under
//!   the same bound, so shutdown-while-inflight cannot hang the
//!   process. Socket I/O is bounded by [`ServeOptions::io_timeout`]
//!   (both directions), so slow-loris readers and writers expire
//!   instead of pinning connection threads.

pub mod cache;
pub mod http;
pub mod queue;
pub mod stats;

pub use cache::{fnv1a, ResponseCache};
pub use queue::{BoundedQueue, PushError};
pub use stats::{LatencySummary, ServeStats};

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::analysis::dedup_rows;
use crate::benchmark::{Harness, HarnessOptions};
use crate::instance::ProblemInstance;
use crate::ranks::RankBackend;
use crate::scheduler::{fused, CancelToken, Cancelled, SchedulerConfig, SchedulerWorkspace};
use crate::util::error::{Context, Result};
use crate::util::{panic_message, FromJson, Value};

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Listen address; port 0 binds an ephemeral port (read it back
    /// from [`Server::local_addr`]).
    pub addr: String,
    /// Worker threads, each holding one warm workspace across requests.
    pub workers: usize,
    /// Bounded queue depth; pushes beyond it are rejected with 429.
    pub queue_depth: usize,
    /// Default per-request deadline (a request's `timeout_ms` field
    /// overrides it).
    pub default_timeout: Duration,
    /// Response-cache capacity in entries (0 disables caching).
    pub cache_size: usize,
    /// Scheduler set swept per request.
    pub schedulers: Vec<SchedulerConfig>,
    /// Queue backlog at which new requests degrade to the portfolio
    /// fast path ([`SchedulerConfig::portfolio`]) instead of the full
    /// sweep: `queue.len() >= degrade_threshold` at enqueue time.
    /// `0` disables degradation (the `--degrade-threshold` flag).
    pub degrade_threshold: usize,
    /// Per-direction socket timeout on connection streams (the
    /// `--io-timeout-ms` flag): idle keep-alive readers, slow-loris
    /// writers, and stalled response writes all expire under it
    /// instead of pinning a connection thread.
    pub io_timeout: Duration,
    /// How long shutdown lets in-flight sweeps and live connections
    /// drain before the watchdog cancels the former and stops waiting
    /// on the latter. Bounds [`Server::wait`] after shutdown.
    pub drain_grace: Duration,
    /// Honor the `debug_sleep_ms` / `debug_panic` /
    /// `debug_cancel_after` request fields — deterministic hooks for
    /// exercising the backpressure, timeout, cancellation, and
    /// panic-containment paths in tests. Off in production.
    pub debug: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7463".into(),
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_depth: 64,
            default_timeout: Duration::from_millis(30_000),
            cache_size: 256,
            schedulers: SchedulerConfig::all(),
            degrade_threshold: 0,
            io_timeout: Duration::from_secs(30),
            drain_grace: Duration::from_secs(5),
            debug: false,
        }
    }
}

/// What a worker sends back for one job.
#[derive(Debug)]
enum JobReply {
    /// The deterministic result payload (also what the cache stores).
    Ok(Arc<Value>),
    /// The job panicked; contained, with this message.
    Failed(String),
    /// The sweep was cancelled mid-run (deadline expired, a debug
    /// cancel hook tripped, or shutdown's drain grace ran out).
    Cancelled,
}

/// One queued `/schedule` request.
#[derive(Debug)]
struct Job {
    inst: ProblemInstance,
    deadline: Instant,
    /// Cooperative-cancellation token the sweep polls per iteration:
    /// a child of the server's shutdown token carrying this request's
    /// deadline (plus the `debug_cancel_after` budget when set).
    cancel: CancelToken,
    /// Run the portfolio fast path instead of the full sweep.
    degraded: bool,
    debug_sleep_ms: u64,
    debug_panic: bool,
    /// Rendezvous back to the connection thread. Capacity 1, so a
    /// worker's send never blocks even when the requester already
    /// timed out and hung up.
    reply: SyncSender<JobReply>,
}

/// State shared by the acceptor, connection, and worker threads.
#[derive(Debug)]
struct Inner {
    opts: ServeOptions,
    queue: BoundedQueue<Job>,
    cache: ResponseCache,
    stats: ServeStats,
    shutdown: AtomicBool,
    /// Root of every job's cancellation chain. Cancelled only by the
    /// shutdown watchdog once [`ServeOptions::drain_grace`] runs out —
    /// a prompt shutdown never aborts in-flight work.
    cancel_root: CancelToken,
    /// Live connection threads (incremented by the acceptor *before*
    /// spawning, decremented by a drop guard in the thread), so
    /// shutdown can wait connections out under the drain bound.
    conns: AtomicUsize,
    local_addr: SocketAddr,
}

/// A running daemon. Dropping the server shuts it down cleanly.
#[derive(Debug)]
pub struct Server {
    inner: Arc<Inner>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind `opts.addr` and start the acceptor + worker pool. Returns
    /// once the listener is live (requests can be sent immediately).
    pub fn start(opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let inner = Arc::new(Inner {
            queue: BoundedQueue::new(opts.queue_depth),
            cache: ResponseCache::new(opts.cache_size),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            cancel_root: CancelToken::never(),
            conns: AtomicUsize::new(0),
            local_addr,
            opts,
        });
        let workers = (0..inner.opts.workers.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();
        let acceptor = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || accept_loop(&listener, &inner))
        };
        Ok(Server { inner, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr
    }

    /// Live serving counters (same data as `GET /stats`).
    pub fn stats(&self) -> &ServeStats {
        &self.inner.stats
    }

    /// Signal shutdown without blocking: close the queue (workers
    /// drain what's left and exit) and wake the acceptor. Idempotent;
    /// `POST /shutdown` triggers exactly this.
    pub fn request_shutdown(&self) {
        request_shutdown(&self.inner);
    }

    /// Block until the acceptor and every worker have exited, then
    /// wait out live connection threads — all under a bounded drain.
    ///
    /// The acceptor exits only on shutdown, so everything after its
    /// join is drain logic: a watchdog gives queued + in-flight work
    /// [`ServeOptions::drain_grace`] and then cancels the root token,
    /// aborting any still-running sweep cooperatively (counted in
    /// `cancelled_requests`). Workers therefore join within the grace
    /// plus one cancellation poll interval, and shutdown-while-inflight
    /// cannot hang. Detached connection threads get the same bound:
    /// with I/O capped by [`ServeOptions::io_timeout`] they normally
    /// finish their last write and exit; a pathological peer merely
    /// costs the grace, never a hang.
    pub fn wait(&mut self) {
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let drained = Arc::new(AtomicBool::new(false));
        let watchdog = {
            let inner = Arc::clone(&self.inner);
            let drained = Arc::clone(&drained);
            std::thread::spawn(move || {
                let deadline = Instant::now() + inner.opts.drain_grace;
                while !drained.load(Ordering::SeqCst) {
                    if Instant::now() >= deadline {
                        inner.cancel_root.cancel();
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            })
        };
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        drained.store(true, Ordering::SeqCst);
        let _ = watchdog.join();
        let conn_deadline = Instant::now() + self.inner.opts.drain_grace;
        while self.inner.conns.load(Ordering::SeqCst) > 0 && Instant::now() < conn_deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// [`Server::request_shutdown`] then [`Server::wait`].
    pub fn shutdown(&mut self) {
        self.request_shutdown();
        self.wait();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn request_shutdown(inner: &Inner) {
    if inner.shutdown.swap(true, Ordering::SeqCst) {
        return; // already requested
    }
    inner.queue.close();
    // Self-connect to pop the acceptor out of its blocking accept();
    // it re-checks the flag per connection.
    let _ = TcpStream::connect(inner.local_addr);
}

fn accept_loop(listener: &TcpListener, inner: &Arc<Inner>) {
    for conn in listener.incoming() {
        if inner.shutdown.load(Ordering::SeqCst) {
            // Listener drops on return: further connects are refused.
            break;
        }
        let Ok(stream) = conn else { continue };
        let inner = Arc::clone(inner);
        // Counted before the spawn so a shutdown racing the thread's
        // startup still sees it in the drain accounting.
        inner.conns.fetch_add(1, Ordering::SeqCst);
        // Detached: each connection thread dies with its socket (EOF,
        // I/O timeout, or write failure) and holds only an Arc.
        std::thread::spawn(move || connection_loop(stream, &inner));
    }
}

/// Decrements the live-connection count however the thread exits
/// (clean EOF, timeout, write failure, or an unexpected unwind).
struct ConnGuard<'a>(&'a Inner);

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// `Retry-After` seconds attached to shed responses: 429 means the
/// queue is full *right now* (retry almost immediately), 503 means the
/// daemon is going away (give the orchestrator time to replace it).
fn retry_after_for(status: u16) -> Option<u64> {
    match status {
        429 => Some(1),
        503 => Some(5),
        _ => None,
    }
}

fn connection_loop(stream: TcpStream, inner: &Arc<Inner>) {
    let _guard = ConnGuard(inner);
    // Bounded I/O in both directions: idle keep-alive readers, partial
    // slow-loris writers, and stalled response writes all expire
    // instead of pinning this thread (and a silent client cannot hold
    // shutdown hostage). `--io-timeout-ms` tunes the bound.
    let _ = stream.set_read_timeout(Some(inner.opts.io_timeout));
    let _ = stream.set_write_timeout(Some(inner.opts.io_timeout));
    let mut reader = match stream.try_clone() {
        Ok(s) => io::BufReader::new(s),
        Err(_) => return,
    };
    let mut stream = stream;
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean EOF between requests
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                let _ = http::write_response(&mut stream, 400, &error_body(&e.to_string()), false);
                return;
            }
            Err(_) => return, // timeout / reset
        };
        let (status, body) = route(inner, &req);
        let written = http::write_response_with(
            &mut stream,
            status,
            &body,
            req.keep_alive,
            retry_after_for(status),
        );
        if req.method == "POST" && req.path == "/shutdown" {
            // Respond first, then bring the daemon down.
            request_shutdown(inner);
            return;
        }
        if written.is_err() || !req.keep_alive {
            return;
        }
    }
}

fn route(inner: &Arc<Inner>, req: &http::Request) -> (u16, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/schedule") => handle_schedule(inner, &req.body),
        ("GET", "/stats") => (200, stats_json(inner).to_string()),
        ("GET", "/healthz") => (200, r#"{"ok":true}"#.to_string()),
        ("POST", "/shutdown") => (200, r#"{"shutting_down":true}"#.to_string()),
        ("GET" | "POST", _) => (404, error_body("no such endpoint")),
        _ => (405, error_body("method not allowed")),
    }
}

/// The `/schedule` flow: cache lookup on the raw bytes, then parse +
/// validate, then enqueue with explicit backpressure and await the
/// worker's reply under the request deadline.
fn handle_schedule(inner: &Arc<Inner>, body: &str) -> (u16, String) {
    let t0 = Instant::now();
    inner.stats.requests_total.fetch_add(1, Ordering::Relaxed);

    let key = fnv1a(body.as_bytes());
    if let Some(payload) = inner.cache.get(key) {
        // Byte-identical resubmission: scheduling is deterministic, so
        // the stored payload IS the answer — no parsing, no warm-up,
        // no sweep. Only full-sweep answers ever enter the cache, so a
        // hit is never degraded.
        inner.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
        inner.stats.requests_ok.fetch_add(1, Ordering::Relaxed);
        let resp = envelope(&payload, true, false, t0);
        inner.stats.record_latency(elapsed_us(t0));
        return (200, resp);
    }
    inner.stats.cache_misses.fetch_add(1, Ordering::Relaxed);

    let parsed = match parse_schedule_request(inner, body) {
        Ok(parsed) => parsed,
        Err(msg) => {
            inner.stats.requests_bad.fetch_add(1, Ordering::Relaxed);
            return (400, error_body(&msg));
        }
    };

    // Degradation ladder, step two (step three is the 429 below): with
    // a backlog at or past the threshold, answer from the portfolio
    // fast path rather than queueing another full sweep.
    let degraded = inner.opts.degrade_threshold > 0
        && inner.queue.len() >= inner.opts.degrade_threshold;

    let deadline = t0 + parsed.timeout;
    let mut cancel = inner.cancel_root.child_with_deadline(deadline);
    if parsed.debug_cancel_after > 0 {
        cancel = cancel.child_after_checks(parsed.debug_cancel_after);
    }
    let (reply_tx, reply_rx) = sync_channel(1);
    let job = Job {
        inst: parsed.inst,
        deadline,
        cancel: cancel.clone(),
        degraded,
        debug_sleep_ms: parsed.debug_sleep_ms,
        debug_panic: parsed.debug_panic,
        reply: reply_tx,
    };
    if let Err((_, e)) = inner.queue.try_push(job) {
        return match e {
            PushError::Full => {
                inner.stats.requests_rejected.fetch_add(1, Ordering::Relaxed);
                (429, error_body("queue full — retry later"))
            }
            PushError::Closed => (503, error_body("shutting down")),
        };
    }
    match reply_rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
        Ok(JobReply::Ok(payload)) => {
            if degraded {
                inner.stats.requests_degraded.fetch_add(1, Ordering::Relaxed);
            } else {
                // Degraded answers are never cached: the same body must
                // get the full sweep once the pressure clears.
                inner.cache.insert(key, Arc::clone(&payload));
            }
            inner.stats.requests_ok.fetch_add(1, Ordering::Relaxed);
            let resp = envelope(&payload, false, degraded, t0);
            inner.stats.record_latency(elapsed_us(t0));
            (200, resp)
        }
        Ok(JobReply::Failed(msg)) => {
            inner.stats.requests_failed.fetch_add(1, Ordering::Relaxed);
            (500, error_body(&format!("scheduling failed: {msg}")))
        }
        Ok(JobReply::Cancelled) => {
            // Reachable before the deadline only via shutdown's drain
            // watchdog or the deterministic debug cancel hook.
            if inner.shutdown.load(Ordering::SeqCst) {
                (503, error_body("shutting down"))
            } else {
                inner.stats.requests_timed_out.fetch_add(1, Ordering::Relaxed);
                (408, error_body("cancelled mid-sweep"))
            }
        }
        Err(RecvTimeoutError::Timeout) => {
            // The job may still be queued (its worker will notice the
            // expired deadline and skip it) or mid-sweep — the deadline
            // baked into its token trips at the next iteration poll, so
            // the worker aborts instead of finishing a sweep nobody is
            // waiting for. Cancel explicitly too, for belt and braces.
            cancel.cancel();
            inner.stats.requests_timed_out.fetch_add(1, Ordering::Relaxed);
            (408, error_body("deadline exceeded"))
        }
        Err(RecvTimeoutError::Disconnected) => (503, error_body("shutting down")),
    }
}

/// The validated fields of one `/schedule` body.
struct ParsedRequest {
    inst: ProblemInstance,
    timeout: Duration,
    debug_sleep_ms: u64,
    debug_panic: bool,
    /// Cancel the job's token on its nth cooperative poll — the
    /// deterministic mid-sweep-cancellation hook (debug mode only).
    debug_cancel_after: u64,
}

fn parse_schedule_request(inner: &Inner, body: &str) -> std::result::Result<ParsedRequest, String> {
    let doc = crate::util::parse(body)?;
    let inst = ProblemInstance::from_json(doc.req("instance")?)?;
    inst.validate()?;
    let timeout = match doc.get("timeout_ms") {
        None => inner.opts.default_timeout,
        Some(v) => {
            let ms = v.as_u64().ok_or("field `timeout_ms` not a u64")?;
            if ms == 0 {
                return Err("`timeout_ms` must be >= 1".into());
            }
            Duration::from_millis(ms)
        }
    };
    let (mut debug_sleep_ms, mut debug_panic, mut debug_cancel_after) = (0, false, 0);
    if inner.opts.debug {
        debug_sleep_ms = doc.get("debug_sleep_ms").and_then(Value::as_u64).unwrap_or(0);
        debug_panic = doc.get("debug_panic").and_then(Value::as_bool).unwrap_or(false);
        debug_cancel_after =
            doc.get("debug_cancel_after").and_then(Value::as_u64).unwrap_or(0);
    }
    Ok(ParsedRequest { inst, timeout, debug_sleep_ms, debug_panic, debug_cancel_after })
}

/// Worker: one warm [`SchedulerWorkspace`] for the thread's lifetime.
/// After the first couple of requests have grown its buffers, every
/// further request of comparable size runs allocation-free — the
/// counter test in `tests/integration_ctx.rs` pins this across N
/// requests, not just within one sweep.
fn worker_loop(inner: &Inner) {
    let mut ws = SchedulerWorkspace::new();
    let harness = Harness {
        schedulers: inner.opts.schedulers.clone(),
        backend: RankBackend::Native,
        options: HarnessOptions::default(),
    };
    // The degraded fast path's fixed scheduler set. Same fused engine,
    // same workspace: its answers are bit-identical to each portfolio
    // config's standalone run (the fused-sweep contract), just five
    // configs instead of the full sweep.
    let portfolio = Harness {
        schedulers: SchedulerConfig::portfolio(),
        backend: RankBackend::Native,
        options: HarnessOptions::default(),
    };
    while let Some(job) = inner.queue.pop() {
        if Instant::now() >= job.deadline {
            // Expired while queued: the requester already answered 408;
            // don't burn a sweep on a result nobody is waiting for.
            continue;
        }
        let h = if job.degraded { &portfolio } else { &harness };
        let outcome = catch_unwind(AssertUnwindSafe(|| run_schedule_job(h, &mut ws, &job)));
        let reply = match outcome {
            Ok(Ok(payload)) => JobReply::Ok(Arc::new(payload)),
            Ok(Err(Cancelled)) => {
                // The sweep aborted cooperatively and returned every
                // buffer to the pools: `ws` stays warm for the next
                // job — no replacement, no new allocations.
                inner.stats.requests_cancelled.fetch_add(1, Ordering::Relaxed);
                JobReply::Cancelled
            }
            Err(payload) => {
                // Same containment policy as `Coordinator::run_jobs`:
                // the daemon must outlive any one bad request. The
                // workspace may be mid-update — replace it.
                ws = SchedulerWorkspace::new();
                JobReply::Failed(panic_message(payload.as_ref()))
            }
        };
        // The requester may have timed out and hung up; capacity-1
        // rendezvous means this send never blocks either way.
        let _ = job.reply.send(reply);
    }
}

/// Run one request's sweep and shape the deterministic result payload
/// (what the cache stores; the per-response envelope wraps it).
/// [`Cancelled`] means the job's token tripped mid-run and the
/// workspace was already returned to clean by the sweep itself.
fn run_schedule_job(
    harness: &Harness,
    ws: &mut SchedulerWorkspace,
    job: &Job,
) -> std::result::Result<Value, Cancelled> {
    if job.debug_sleep_ms > 0 {
        // Sliced so a cancelled or shutdown-drained request frees its
        // worker promptly instead of sleeping out the full duration.
        let mut left = Duration::from_millis(job.debug_sleep_ms);
        while !left.is_zero() {
            if job.cancel.is_cancelled() {
                return Err(Cancelled);
            }
            let step = left.min(Duration::from_millis(10));
            std::thread::sleep(step);
            left -= step;
        }
    }
    if job.debug_panic {
        panic!("debug_panic requested");
    }
    let inst = &job.inst;
    let records = harness.try_run_instance_ws(&inst.name, 0, inst, ws, &job.cancel)?;
    let dedup = dedup_rows(&records);
    let results = Value::Arr(
        records
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("scheduler", Value::Str(r.scheduler.clone())),
                    ("makespan", Value::Num(r.makespan)),
                ];
                if let Some(h) = r.schedule_hash {
                    fields.push(("schedule_hash", Value::Str(format!("{h:016x}"))));
                }
                Value::obj(fields)
            })
            .collect(),
    );
    let (distinct, classes) = match dedup.first() {
        Some(row) => (
            row.distinct_schedules,
            Value::Arr(
                row.classes
                    .iter()
                    .map(|class| {
                        Value::Arr(class.iter().map(|s| Value::Str(s.clone())).collect())
                    })
                    .collect(),
            ),
        ),
        None => (0, Value::Arr(Vec::new())),
    };
    Ok(Value::obj(vec![
        ("instance", Value::Str(inst.name.clone())),
        ("num_tasks", Value::Num(inst.graph.len() as f64)),
        ("num_nodes", Value::Num(inst.network.len() as f64)),
        ("results", results),
        ("distinct_schedules", Value::Num(distinct as f64)),
        ("equivalence_classes", classes),
    ]))
}

/// Wrap the deterministic payload with the per-response fields. Only
/// the envelope varies between a fresh, a cached, and a degraded
/// answer; `degraded: true` marks a portfolio fast-path response.
fn envelope(payload: &Value, cached: bool, degraded: bool, t0: Instant) -> String {
    Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("cached", Value::Bool(cached)),
        ("degraded", Value::Bool(degraded)),
        ("latency_us", Value::Num(elapsed_us(t0) as f64)),
        ("payload", payload.clone()),
    ])
    .to_string()
}

fn error_body(msg: &str) -> String {
    Value::obj(vec![("ok", Value::Bool(false)), ("error", Value::Str(msg.to_string()))])
        .to_string()
}

fn elapsed_us(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_micros()).unwrap_or(u64::MAX)
}

fn stats_json(inner: &Inner) -> Value {
    let s = &inner.stats;
    let count = |c: &std::sync::atomic::AtomicU64| Value::Num(c.load(Ordering::Relaxed) as f64);
    let lat = s.latency_summary();
    Value::obj(vec![
        ("queue_depth", Value::Num(inner.queue.len() as f64)),
        ("queue_capacity", Value::Num(inner.queue.capacity() as f64)),
        ("workers", Value::Num(inner.opts.workers.max(1) as f64)),
        ("requests_total", count(&s.requests_total)),
        ("requests_ok", count(&s.requests_ok)),
        ("requests_rejected", count(&s.requests_rejected)),
        ("requests_timed_out", count(&s.requests_timed_out)),
        ("requests_failed", count(&s.requests_failed)),
        ("requests_bad", count(&s.requests_bad)),
        ("degraded_requests", count(&s.requests_degraded)),
        ("cancelled_requests", count(&s.requests_cancelled)),
        ("connections_live", Value::Num(inner.conns.load(Ordering::SeqCst) as f64)),
        ("cache_entries", Value::Num(inner.cache.len() as f64)),
        ("cache_hits", count(&s.cache_hits)),
        ("cache_misses", count(&s.cache_misses)),
        ("cache_hit_rate", Value::Num(s.cache_hit_rate())),
        // Process-wide scheduling-core counters: deltas between reads
        // track the fused engine's sharing behavior under live traffic.
        ("window_scans", Value::Num(fused::window_scans() as f64)),
        ("fork_events", Value::Num(fused::fork_events() as f64)),
        (
            "buffer_allocations",
            Value::Num(SchedulerWorkspace::buffer_allocations() as f64),
        ),
        (
            "latency",
            Value::obj(vec![
                ("count", Value::Num(lat.count as f64)),
                ("p50_us", Value::Num(lat.p50_us as f64)),
                ("p99_us", Value::Num(lat.p99_us as f64)),
                ("max_us", Value::Num(lat.max_us as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{DatasetSpec, Structure};

    fn tiny_options() -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:0".into(),
            workers: 1,
            schedulers: vec![SchedulerConfig::heft(), SchedulerConfig::mct()],
            ..ServeOptions::default()
        }
    }

    fn tiny_body() -> String {
        body_with(Vec::new())
    }

    /// A valid `/schedule` body with extra top-level request fields.
    fn body_with(extra: Vec<(&str, Value)>) -> String {
        use crate::util::ToJson;
        let spec = DatasetSpec { count: 1, ..DatasetSpec::new(Structure::Chains, 1.0) };
        let mut rng = spec.instance_rng(0);
        let inst = spec.generate_one(&mut rng);
        let mut fields = vec![("instance", inst.to_json())];
        fields.extend(extra);
        Value::obj(fields).to_string()
    }

    #[test]
    fn ephemeral_start_schedule_and_clean_shutdown() {
        let mut server = Server::start(tiny_options()).unwrap();
        let addr = server.local_addr().to_string();
        let (status, body) = http::roundtrip(&addr, "GET", "/healthz", "").unwrap();
        assert_eq!((status, body.as_str()), (200, r#"{"ok":true}"#));
        let (status, body) = http::roundtrip(&addr, "POST", "/schedule", &tiny_body()).unwrap();
        assert_eq!(status, 200, "{body}");
        let doc = crate::util::parse(&body).unwrap();
        assert!(doc.req_bool("ok").unwrap());
        let payload = doc.req("payload").unwrap();
        assert_eq!(payload.req_arr("results").unwrap().len(), 2);
        server.shutdown();
        // Idempotent: a second shutdown (and the Drop) are no-ops.
        server.shutdown();
    }

    #[test]
    fn stats_endpoint_exposes_the_documented_fields() {
        let mut server = Server::start(tiny_options()).unwrap();
        let addr = server.local_addr().to_string();
        let (_, _) = http::roundtrip(&addr, "POST", "/schedule", &tiny_body()).unwrap();
        let (status, body) = http::roundtrip(&addr, "GET", "/stats", "").unwrap();
        assert_eq!(status, 200);
        let doc = crate::util::parse(&body).unwrap();
        for field in [
            "queue_depth",
            "queue_capacity",
            "requests_total",
            "requests_ok",
            "requests_rejected",
            "requests_timed_out",
            "requests_failed",
            "requests_bad",
            "degraded_requests",
            "cancelled_requests",
            "connections_live",
            "cache_entries",
            "cache_hits",
            "cache_misses",
            "window_scans",
            "fork_events",
            "buffer_allocations",
        ] {
            assert!(doc.req_u64(field).is_ok(), "missing /stats field {field}: {body}");
        }
        doc.req_f64("cache_hit_rate").unwrap();
        let lat = doc.req("latency").unwrap();
        assert!(lat.req_u64("count").unwrap() >= 1);
        lat.req_u64("p50_us").unwrap();
        lat.req_u64("p99_us").unwrap();
        server.shutdown();
    }

    #[test]
    fn debug_cancel_hook_aborts_mid_sweep_with_408() {
        // cache_size 0 so every request actually reaches the worker —
        // the post-cancellation 200 then proves the workspace survived.
        let mut server = Server::start(ServeOptions {
            cache_size: 0,
            debug: true,
            ..tiny_options()
        })
        .unwrap();
        let addr = server.local_addr().to_string();

        let (status, body) = http::roundtrip(&addr, "POST", "/schedule", &tiny_body()).unwrap();
        assert_eq!(status, 200, "warm-up: {body}");

        // Budget 1: the sweep's second cooperative poll trips the token
        // — a deterministic mid-sweep abort, no wall clock involved.
        let cancel_body = body_with(vec![("debug_cancel_after", Value::Num(1.0))]);
        let (status, body) = http::roundtrip(&addr, "POST", "/schedule", &cancel_body).unwrap();
        assert_eq!(status, 408, "{body}");
        assert!(body.contains("cancelled"), "{body}");
        assert_eq!(server.stats().requests_cancelled.load(Ordering::Relaxed), 1);

        // Same worker, same workspace: the next full request succeeds.
        let (status, body) = http::roundtrip(&addr, "POST", "/schedule", &tiny_body()).unwrap();
        assert_eq!(status, 200, "worker must survive a cancelled sweep: {body}");
        server.shutdown();
    }

    #[test]
    fn pressure_degrades_to_portfolio_and_skips_the_cache() {
        let mut server = Server::start(ServeOptions {
            degrade_threshold: 1,
            debug: true,
            ..tiny_options()
        })
        .unwrap();
        let addr = server.local_addr().to_string();

        // Occupy the single worker, then park one job in the queue so
        // the next enqueue sees a backlog at the threshold.
        let spawn_sleeper = |ms: f64| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = body_with(vec![("debug_sleep_ms", Value::Num(ms))]);
                http::roundtrip(&addr, "POST", "/schedule", &body).unwrap()
            })
        };
        let a = spawn_sleeper(500.0);
        std::thread::sleep(Duration::from_millis(50));
        let b = spawn_sleeper(1.0);
        for _ in 0..400 {
            if server.inner.queue.len() >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(server.inner.queue.len() >= 1, "sleeper never queued");

        let degraded_body = tiny_body();
        let (status, body) =
            http::roundtrip(&addr, "POST", "/schedule", &degraded_body).unwrap();
        assert_eq!(status, 200, "{body}");
        let doc = crate::util::parse(&body).unwrap();
        assert!(doc.req_bool("degraded").unwrap(), "{body}");
        let results = doc.req("payload").unwrap().req_arr("results").unwrap();
        let mut got: Vec<String> = results
            .iter()
            .map(|r| r.req_str("scheduler").unwrap().to_string())
            .collect();
        let mut want: Vec<String> =
            SchedulerConfig::portfolio().iter().map(|c| c.name()).collect();
        got.sort();
        want.sort();
        assert_eq!(got, want, "portfolio answer: {body}");
        assert_eq!(server.stats().requests_degraded.load(Ordering::Relaxed), 1);
        a.join().unwrap();
        b.join().unwrap();

        // The degraded answer must not have been cached: the same body
        // under no pressure gets the fresh full sweep.
        let (status, body) =
            http::roundtrip(&addr, "POST", "/schedule", &degraded_body).unwrap();
        assert_eq!(status, 200, "{body}");
        let doc = crate::util::parse(&body).unwrap();
        assert!(!doc.req_bool("degraded").unwrap(), "{body}");
        assert!(!doc.req_bool("cached").unwrap(), "degraded reply leaked into cache: {body}");
        let results = doc.req("payload").unwrap().req_arr("results").unwrap();
        assert_eq!(results.len(), 2, "full sweep over tiny_options' two configs: {body}");
        server.shutdown();
    }

    #[test]
    fn full_queue_sheds_with_retry_after() {
        let mut server = Server::start(ServeOptions {
            queue_depth: 1,
            debug: true,
            ..tiny_options()
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let sleeper = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = body_with(vec![("debug_sleep_ms", Value::Num(500.0))]);
                http::roundtrip(&addr, "POST", "/schedule", &body).unwrap()
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        let filler = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = body_with(vec![("debug_sleep_ms", Value::Num(1.0))]);
                http::roundtrip(&addr, "POST", "/schedule", &body).unwrap()
            })
        };
        for _ in 0..400 {
            if server.inner.queue.len() >= 1 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut client = http::Client::connect(&addr).unwrap();
        let resp = client.request_detailed("POST", "/schedule", &tiny_body()).unwrap();
        assert_eq!(resp.status, 429, "{}", resp.body);
        assert_eq!(resp.retry_after, Some(1), "429 must carry Retry-After");
        assert_eq!(server.stats().requests_rejected.load(Ordering::Relaxed), 1);
        sleeper.join().unwrap();
        filler.join().unwrap();
        server.shutdown();
    }

    #[test]
    fn shutdown_while_inflight_is_bounded_by_drain_grace() {
        let mut server = Server::start(ServeOptions {
            drain_grace: Duration::from_millis(100),
            debug: true,
            ..tiny_options()
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        // Park a job that would otherwise pin its worker for 60 s.
        let inflight = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let body = body_with(vec![("debug_sleep_ms", Value::Num(60_000.0))]);
                http::roundtrip(&addr, "POST", "/schedule", &body)
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "drain must be bounded by the grace, not the job ({:?})",
            t0.elapsed()
        );
        assert_eq!(server.stats().requests_cancelled.load(Ordering::Relaxed), 1);
        // The cancelled requester was answered (503 during shutdown),
        // not left hanging on a dead socket.
        let (status, _) = inflight.join().unwrap().expect("in-flight request must get a reply");
        assert_eq!(status, 503);
    }

    #[test]
    fn unknown_routes_and_methods() {
        let mut server = Server::start(tiny_options()).unwrap();
        let addr = server.local_addr().to_string();
        let (status, _) = http::roundtrip(&addr, "GET", "/nope", "").unwrap();
        assert_eq!(status, 404);
        let (status, _) = http::roundtrip(&addr, "PUT", "/schedule", "{}").unwrap();
        assert_eq!(status, 405);
        server.shutdown();
    }
}
