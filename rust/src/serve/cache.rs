//! Response cache keyed by content hash of the raw request body.
//!
//! Scheduling is deterministic, so two byte-identical `/schedule`
//! bodies produce byte-identical result payloads — a repeat submission
//! can skip JSON parsing, `SchedulingContext` warm-up, and the whole
//! fused sweep. Keys are FNV-1a over the body bytes, the same hash
//! family as [`crate::schedule::Schedule::content_hash`] (64-bit FNV
//! offset/prime), applied to bytes instead of assignment words.
//! Eviction is FIFO by first insertion — requests are content-addressed
//! and the cache is a warm-start optimization, not a source of truth,
//! so recency bookkeeping isn't worth a second lock touch per hit.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::util::Value;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over raw bytes.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[derive(Debug, Default)]
struct CacheState {
    map: HashMap<u64, Arc<Value>>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<u64>,
}

/// FIFO-evicting response cache; capacity 0 disables caching entirely
/// (every lookup misses, every insert is dropped).
#[derive(Debug)]
pub struct ResponseCache {
    state: Mutex<CacheState>,
    capacity: usize,
}

impl ResponseCache {
    /// FIFO cache holding at most `capacity` responses (0 disables).
    pub fn new(capacity: usize) -> Self {
        ResponseCache { state: Mutex::new(CacheState::default()), capacity }
    }

    fn lock(&self) -> MutexGuard<'_, CacheState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Cached response for `key`, if present.
    pub fn get(&self, key: u64) -> Option<Arc<Value>> {
        if self.capacity == 0 {
            return None;
        }
        self.lock().map.get(&key).cloned()
    }

    /// Insert a response, evicting oldest-first past capacity.
    pub fn insert(&self, key: u64, payload: Arc<Value>) {
        if self.capacity == 0 {
            return;
        }
        let mut s = self.lock();
        if s.map.insert(key, payload).is_none() {
            s.order.push_back(key);
            while s.map.len() > self.capacity {
                if let Some(old) = s.order.pop_front() {
                    s.map.remove(&old);
                }
            }
        }
    }

    /// Number of cached responses.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Cache currently empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Standard FNV-1a test vectors (64-bit).
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let cache = ResponseCache::new(4);
        let key = fnv1a(b"{\"instance\":{}}");
        assert!(cache.get(key).is_none());
        cache.insert(key, Arc::new(Value::Bool(true)));
        assert_eq!(*cache.get(key).unwrap(), Value::Bool(true));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn fifo_eviction_at_capacity() {
        let cache = ResponseCache::new(2);
        cache.insert(1, Arc::new(Value::Num(1.0)));
        cache.insert(2, Arc::new(Value::Num(2.0)));
        cache.insert(3, Arc::new(Value::Num(3.0))); // evicts key 1
        assert!(cache.get(1).is_none());
        assert!(cache.get(2).is_some());
        assert!(cache.get(3).is_some());
        assert_eq!(cache.len(), 2);
        // Re-inserting an existing key does not grow or double-track.
        cache.insert(3, Arc::new(Value::Num(3.0)));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn zero_capacity_disables() {
        let cache = ResponseCache::new(0);
        cache.insert(7, Arc::new(Value::Null));
        assert!(cache.get(7).is_none());
        assert!(cache.is_empty());
    }
}
