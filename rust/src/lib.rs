//! # PTGS — Parameterized Task Graph Scheduling
//!
//! A production-grade Rust reproduction of Coleman, Agrawal, Hirani &
//! Krishnamachari, *"Parameterized Task Graph Scheduling Algorithm for
//! Comparing Algorithmic Components"* (CS.DC 2024), with the dense rank
//! computation AOT-compiled from JAX/Pallas and executed via PJRT.
//!
//! The crate provides:
//!
//! * [`graph`] / [`network`] / [`instance`] — the heterogeneous DAG
//!   scheduling problem model (related-machines; see paper §I-A).
//!   Task-graph adjacency freezes into a CSR layout (flat edge arrays +
//!   per-task offsets) on first query, sized for 10k–100k-task
//!   workflow instances.
//! * [`schedule`] — schedules, the makespan objective, and a strict
//!   validity checker for the four §I-A properties.
//! * [`ranks`] — UpwardRank / DownwardRank / CPoP rank and critical-path
//!   extraction, with a pure-Rust engine and an XLA (PJRT) engine running
//!   the AOT-compiled Pallas tropical-algebra kernels.
//! * [`scheduler`] — the paper's contribution: the generalized parametric
//!   list scheduler whose 5 components span 72 algorithms (HEFT, CPoP,
//!   MCT, MET, Sufferage, … as special cases). Sweeps share one
//!   [`scheduler::SchedulingContext`] per instance (ranks, priorities,
//!   pins, topo order computed once, never per config) and one
//!   reusable [`scheduler::SchedulerWorkspace`] per worker thread
//!   (scratch buffers allocated once, recycled per config). Multi-config
//!   sweeps default to the **fused engine**
//!   ([`scheduler::fused_sweep`]): lockstep groups share one loop state
//!   and one window scan per candidate until their placement decisions
//!   diverge, forking copy-on-diverge; `schedule_into` remains the
//!   per-config zero-recompute core, and the pre-refactor loop remains
//!   as `schedule_reference` — both bit-exactness oracles.
//! * [`datasets`] — the 4×5 benchmark dataset families of §III
//!   (in_trees, out_trees, chains, cycles × CCR ∈ {1/5, 1/2, 1, 2, 5}),
//!   plus [`datasets::layered`]: the layered wide-DAG scale axis
//!   (~100k tasks, `benches/bench_scale.rs`),
//!   and [`datasets::traces`]: real workflow-trace ingestion (WfCommons
//!   JSON and simple DSLab-style DAG descriptions → [`instance`]s, with
//!   machine-spec or synthetic network attachment and CCR rescaling).
//! * [`benchmark`] — the 72-algorithm sweep harness producing makespan /
//!   runtime ratios.
//! * [`coordinator`] — std::thread leader/worker parallel benchmark execution
//!   with sharding and bounded-channel backpressure.
//! * [`sim`] — the event-driven schedule execution simulator: replay a
//!   plan under multiplicative lognormal noise and node slowdowns
//!   (statically, or with online rescheduling) and measure how far the
//!   realized makespan drifts from the plan. Zero noise reproduces the
//!   static makespan bit-exactly; robustness ratios surface through
//!   [`benchmark::Harness`] / [`coordinator`] sweeps and the
//!   [`analysis::robustness_table`].
//! * [`serve`] — scheduling as a service: the `ptgs serve` daemon
//!   (in-crate HTTP/1.1, pure `std::thread`) running the fused sweep
//!   per request, with a bounded job queue (429 backpressure),
//!   per-request deadlines, warm per-worker workspaces, and a
//!   content-hash response cache.
//! * [`analysis`] — pareto fronts, per-component effects, pairwise
//!   interactions, the robustness table, renderers for every
//!   table/figure in the paper, and the adversarial instance search
//!   ([`analysis::anneal_search`]): simulated-annealing chains scored
//!   by the fused 72-config sweep, with validity-preserving structural
//!   mutations and a content-hash dedup cache.
//! * [`runtime`] — the PJRT client wrapper that loads `artifacts/*.hlo.txt`
//!   (execution requires the off-by-default `xla` cargo feature).
//!
//! ## Quickstart
//!
//! ```no_run
//! use ptgs::prelude::*;
//!
//! let mut rng = Rng::seeded(42);
//! let instance = DatasetSpec::new(Structure::InTrees, 1.0).generate_one(&mut rng);
//! let schedule = SchedulerConfig::heft().build().schedule(&instance);
//! assert!(schedule.validate(&instance).is_ok());
//! println!("makespan = {}", schedule.makespan());
//! ```
//!
//! ## Architecture
//!
//! `ARCHITECTURE.md` at the repository root maps the crate layer by
//! layer — problem model → immutable context → pooled workspaces →
//! scheduling cores → harnesses and services — and states the two
//! invariants every layer upholds: bit-exactness against
//! `schedule_reference` and O(1) heap allocations per warm run.

#![warn(missing_docs)]

pub mod analysis;
pub mod benchlib;
pub mod benchmark;
pub mod coordinator;
pub mod datasets;
pub mod graph;
pub mod instance;
pub mod network;
pub mod ranks;
pub mod runtime;
pub mod schedule;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod util;

/// Convenient re-exports of the main user-facing types.
pub mod prelude {
    pub use crate::benchmark::{
        extended_metrics, BenchmarkResults, ExtendedMetrics, Harness, HarnessOptions,
    };
    pub use crate::datasets::traces::{
        load_trace, parse_trace, to_trace_json, trace_from_value, NetworkSynthesis,
        TraceFormat, TraceOptions, TraceSet,
    };
    pub use crate::datasets::{rng::Rng, DatasetSpec, Structure, CCRS};
    pub use crate::graph::TaskGraph;
    pub use crate::instance::ProblemInstance;
    pub use crate::network::Network;
    pub use crate::ranks::{RankBackend, Ranks};
    pub use crate::schedule::{render_gantt, Schedule};
    pub use crate::scheduler::{
        fused_sweep, CompareFn, FusedGroup, FusedOutcome, FusedStats, LookaheadScheduler,
        ParametricScheduler, PriorityFn, SchedulerConfig, SchedulerWorkspace,
        SchedulingContext,
    };
    pub use crate::benchmark::{SimRecord, SimSweep};
    pub use crate::serve::{ServeOptions, Server};
    pub use crate::sim::{
        fault_horizon, perturbed_instance, replay_faulty, simulate, simulate_against,
        simulate_into, FaultModel, FaultSummary, FaultTrace, NoiseTrace, Perturbation,
        ReplayPolicy, RetryPolicy, SimOptions, SimOutcome,
    };
}
