//! Compute-network model: a complete, undirected, weighted graph.
//!
//! This is the `N = (V, E)` of the paper's §I-A under the *related
//! machines* model: node `v` has speed `s(v)`, link `(v, v')` has
//! communication strength `s(v, v')`. Execution time of task `t` on `v`
//! is `c(t) / s(v)`; transfer time of edge `(t, t')` from `v` to `v'` is
//! `c(t, t') / s(v, v')`, and 0 when `v = v'` (loopback is instantaneous).

use crate::util::{FromJson, ToJson, Value};

/// Index of a compute node within its [`Network`].
pub type NodeId = usize;

/// A complete weighted network of heterogeneous compute nodes.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    /// Node compute speeds `s(v) > 0`.
    speeds: Vec<f64>,
    /// Dense symmetric link-strength matrix, row-major `n × n`;
    /// the diagonal is unused (same-node transfers cost 0).
    links: Vec<f64>,
}

impl Network {
    /// Build a network from node speeds and a symmetric link matrix
    /// given as a flat row-major `n × n` slice.
    pub fn new(speeds: Vec<f64>, links: Vec<f64>) -> Self {
        let n = speeds.len();
        assert_eq!(links.len(), n * n, "link matrix must be n×n");
        for &s in &speeds {
            assert!(s > 0.0, "node speeds must be positive, got {s}");
        }
        for i in 0..n {
            for j in 0..n {
                assert!(
                    (links[i * n + j] - links[j * n + i]).abs() < 1e-12,
                    "link matrix must be symmetric at ({i},{j})"
                );
                if i != j {
                    assert!(links[i * n + j] > 0.0, "link strengths must be positive");
                }
            }
        }
        Network { speeds, links }
    }

    /// Homogeneous network: `n` nodes of speed 1 and link strength `bw`.
    pub fn homogeneous(n: usize, bw: f64) -> Self {
        Network::new(vec![1.0; n], vec![bw; n * n])
    }

    /// Number of nodes `|V|`.
    pub fn len(&self) -> usize {
        self.speeds.len()
    }

    /// True when the network has no nodes.
    pub fn is_empty(&self) -> bool {
        self.speeds.is_empty()
    }

    /// Compute speed `s(v)`.
    pub fn speed(&self, v: NodeId) -> f64 {
        self.speeds[v]
    }

    /// All node speeds.
    pub fn speeds(&self) -> &[f64] {
        &self.speeds
    }

    /// Link strength `s(v, v')`; meaningless for `v == v'` (transfers on
    /// the same node take zero time; see [`Network::comm_time`]).
    pub fn link(&self, v: NodeId, w: NodeId) -> f64 {
        self.links[v * self.len() + w]
    }

    /// Scale every link strength by `factor` (used for CCR normalization).
    pub fn scale_links(&mut self, factor: f64) {
        assert!(factor > 0.0);
        for l in &mut self.links {
            *l *= factor;
        }
    }

    /// Execution time of a task of cost `c` on node `v`: `c / s(v)`.
    pub fn exec_time(&self, cost: f64, v: NodeId) -> f64 {
        cost / self.speeds[v]
    }

    /// Transfer time of `data` units from `v` to `w`: `data / s(v, w)`,
    /// 0 when `v == w`.
    pub fn comm_time(&self, data: f64, v: NodeId, w: NodeId) -> f64 {
        if v == w {
            0.0
        } else {
            data / self.link(v, w)
        }
    }

    /// The fastest node (max speed; ties → smallest id). This is the node
    /// onto which critical-path reservation pins the critical path.
    pub fn fastest_node(&self) -> NodeId {
        let mut best = 0;
        for v in 1..self.len() {
            if self.speeds[v] > self.speeds[best] {
                best = v;
            }
        }
        best
    }

    /// Mean of `1 / s(v)` over nodes — the expected execution time of a
    /// unit-cost task on a uniformly random node. Rank computations use
    /// `c(t) · avg_inv_speed` as the task's mean execution cost.
    pub fn avg_inv_speed(&self) -> f64 {
        self.speeds.iter().map(|s| 1.0 / s).sum::<f64>() / self.len() as f64
    }

    /// Mean of `1 / s(v, v')` over *distinct* node pairs — the expected
    /// transfer time of a unit of data over a uniformly random link.
    /// Single-node networks have no links; returns 0 so that mean
    /// communication costs vanish (everything is local anyway).
    pub fn avg_inv_link(&self) -> f64 {
        let n = self.len();
        if n < 2 {
            return 0.0;
        }
        let mut sum = 0.0;
        for v in 0..n {
            for w in 0..n {
                if v != w {
                    sum += 1.0 / self.link(v, w);
                }
            }
        }
        sum / (n * (n - 1)) as f64
    }
}

impl ToJson for Network {
    /// Wire format: `{"speeds": [...], "links": [...]}` (row-major n×n).
    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("speeds", Value::num_arr(&self.speeds)),
            ("links", Value::num_arr(&self.links)),
        ])
    }
}

impl FromJson for Network {
    fn from_json(v: &Value) -> Result<Self, String> {
        let speeds: Vec<f64> = v
            .req_arr("speeds")?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| "bad speed".to_string()))
            .collect::<Result<_, _>>()?;
        let links: Vec<f64> = v
            .req_arr("links")?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| "bad link".to_string()))
            .collect::<Result<_, _>>()?;
        if links.len() != speeds.len() * speeds.len() {
            return Err("link matrix must be n×n".into());
        }
        Ok(Network::new(speeds, links))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net3() -> Network {
        // speeds 1, 2, 4; links all 2 except (0,1)=1
        let mut links = vec![2.0; 9];
        links[0 * 3 + 1] = 1.0;
        links[1 * 3 + 0] = 1.0;
        Network::new(vec![1.0, 2.0, 4.0], links)
    }

    #[test]
    fn times() {
        let n = net3();
        assert_eq!(n.exec_time(8.0, 0), 8.0);
        assert_eq!(n.exec_time(8.0, 2), 2.0);
        assert_eq!(n.comm_time(6.0, 0, 1), 6.0);
        assert_eq!(n.comm_time(6.0, 0, 2), 3.0);
        assert_eq!(n.comm_time(6.0, 1, 1), 0.0, "loopback is free");
    }

    #[test]
    fn fastest_and_averages() {
        let n = net3();
        assert_eq!(n.fastest_node(), 2);
        let want = (1.0 + 0.5 + 0.25) / 3.0;
        assert!((n.avg_inv_speed() - want).abs() < 1e-12);
        // links: (0,1)=1 twice, others 2 (4 directed entries)
        let want = (2.0 * 1.0 + 4.0 * 0.5) / 6.0;
        assert!((n.avg_inv_link() - want).abs() < 1e-12);
    }

    #[test]
    fn fastest_ties_min_id() {
        let n = Network::homogeneous(4, 1.0);
        assert_eq!(n.fastest_node(), 0);
    }

    #[test]
    fn scale_links() {
        let mut n = net3();
        n.scale_links(0.5);
        assert_eq!(n.comm_time(6.0, 0, 2), 6.0);
    }

    #[test]
    fn single_node_avg_inv_link_zero() {
        let n = Network::homogeneous(1, 1.0);
        assert_eq!(n.avg_inv_link(), 0.0);
    }

    #[test]
    #[should_panic(expected = "symmetric")]
    fn asymmetric_links_panic() {
        let mut links = vec![1.0; 4];
        links[1] = 2.0;
        Network::new(vec![1.0, 1.0], links);
    }

    #[test]
    fn json_roundtrip() {
        let n = net3();
        let text = n.to_json().to_string();
        let back = Network::from_json(&crate::util::parse(&text).unwrap()).unwrap();
        assert_eq!(n, back);
    }
}
