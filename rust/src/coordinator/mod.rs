//! Parallel benchmark coordinator: a leader/worker thread pool that
//! shards the (dataset × instance) space, runs every scheduler on each
//! shard, and streams results back through a **bounded** channel
//! (backpressure: workers stall rather than buffering unboundedly).
//!
//! Determinism: instance generation uses per-instance RNG streams
//! ([`DatasetSpec::instance_rng`]), and scheduling itself is
//! deterministic, so the *makespans* produced by the parallel
//! coordinator are identical to the serial [`Harness`]'s — an
//! integration test pins this. Runtimes are measured per (scheduler,
//! instance) inside the worker, exactly as the serial path does.
//!
//! Implementation note: this environment vendors no async runtime, so
//! the pool is built directly on `std::thread` + `mpsc::sync_channel`
//! (the bounded std channel). The leader owns the job queue; workers
//! pull shards from a shared lock-protected deque (cheap — one lock per
//! *shard*, not per instance) and push result batches through the
//! bounded channel that the aggregating leader drains.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};

use crate::benchmark::{BenchmarkResults, Harness, HarnessOptions, Record, SimRecord, SimSweep};
use crate::datasets::DatasetSpec;
use crate::ranks::RankBackend;
use crate::scheduler::{CancelToken, SchedulerConfig, SchedulerWorkspace};

/// One unit of work: a contiguous instance range of one dataset.
#[derive(Debug, Clone)]
struct Job {
    spec: DatasetSpec,
    start: usize,
    end: usize,
}

/// Records that carry the canonical (dataset, instance, scheduler)
/// identity — the sort key shared by the parallel and serial paths.
pub trait CanonicalOrder {
    fn canonical_key(&self) -> (&str, usize, &str);
}

impl CanonicalOrder for Record {
    fn canonical_key(&self) -> (&str, usize, &str) {
        (self.dataset.as_str(), self.instance, self.scheduler.as_str())
    }
}

impl CanonicalOrder for SimRecord {
    fn canonical_key(&self) -> (&str, usize, &str) {
        (self.dataset.as_str(), self.instance, self.scheduler.as_str())
    }
}

/// Sort records into the canonical (dataset, instance, scheduler)
/// order every coordinator result is reported in.
pub fn sort_canonical<T: CanonicalOrder>(records: &mut [T]) {
    records.sort_by(|a, b| a.canonical_key().cmp(&b.canonical_key()));
}

/// Live progress counters (shared with the caller for monitoring).
///
/// On a run with failures, `jobs_done + jobs_failed == jobs_total` once
/// `run_jobs` returns; [`Metrics::failed_jobs`] names each failed job.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs enqueued for this run.
    pub jobs_total: AtomicUsize,
    /// Jobs that completed and contributed records.
    pub jobs_done: AtomicUsize,
    /// Jobs whose `per_job` panicked: contained, counted, and skipped —
    /// the rest of the sweep keeps running (see [`Coordinator`] docs on
    /// `run_jobs` failure semantics).
    pub jobs_failed: AtomicUsize,
    /// Jobs skipped because [`CoordinatorOptions::cancel`] tripped
    /// before they started. `jobs_done + jobs_failed + jobs_cancelled
    /// == jobs_total` once `run_jobs` returns.
    pub jobs_cancelled: AtomicUsize,
    /// Records received by the leader so far.
    pub records: AtomicUsize,
    /// Identity + panic message of every failed job, in completion
    /// order (`"{job:?}: {panic message}"`).
    pub failed_jobs: Mutex<Vec<String>>,
}

impl Metrics {
    /// Record one contained per-job panic.
    fn note_failure(&self, description: String) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        self.failed_jobs
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(description);
    }
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    /// Worker threads (defaults to available parallelism).
    pub workers: usize,
    /// Instances per job shard.
    pub chunk_size: usize,
    /// Bounded depth of the result channel (backpressure).
    pub channel_depth: usize,
    /// Harness options applied inside each worker.
    pub harness: HarnessOptions,
    /// Cooperative cancellation: once this token trips, workers skip
    /// every not-yet-started job (counted in
    /// [`Metrics::jobs_cancelled`]) and the run returns with whatever
    /// records completed. Granularity is per *job* — a shard already
    /// running finishes normally. Defaults to [`CancelToken::never`].
    pub cancel: CancelToken,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            chunk_size: 10,
            channel_depth: 64,
            harness: HarnessOptions::default(),
            cancel: CancelToken::never(),
        }
    }
}

/// The leader: owns the scheduler set and fans work out to workers.
#[derive(Debug, Clone)]
pub struct Coordinator {
    /// Scheduler configurations every worker runs per instance.
    pub schedulers: Vec<SchedulerConfig>,
    /// Rank backend handed to each worker's harness.
    pub backend: RankBackend,
    /// Threading, sharding, and backpressure knobs.
    pub options: CoordinatorOptions,
}

impl Coordinator {
    /// Coordinator over all 72 schedulers with default options.
    pub fn all_schedulers() -> Self {
        Coordinator {
            schedulers: SchedulerConfig::all(),
            backend: RankBackend::Native,
            options: CoordinatorOptions::default(),
        }
    }

    /// Coordinator over an explicit scheduler list, default options.
    pub fn with_schedulers(schedulers: Vec<SchedulerConfig>) -> Self {
        Coordinator {
            schedulers,
            backend: RankBackend::Native,
            options: CoordinatorOptions::default(),
        }
    }

    /// Shared leader/worker scaffolding over dataset-spec shards.
    fn run_with<R, F>(&self, specs: &[DatasetSpec], per_job: F) -> (Vec<R>, Arc<Metrics>)
    where
        R: Send,
        F: Fn(&Harness, &mut SchedulerWorkspace, &Job) -> Vec<R> + Sync,
    {
        // Shard the instance space.
        let mut jobs: Vec<Job> = Vec::new();
        for spec in specs {
            let mut start = 0;
            while start < spec.count {
                let end = (start + self.options.chunk_size).min(spec.count);
                jobs.push(Job { spec: *spec, start, end });
                start = end;
            }
        }
        self.run_jobs(jobs, per_job)
    }

    /// Contiguous index-range shards over an externally-supplied
    /// instance set (the trace counterpart of the spec sharding).
    fn range_jobs(&self, total: usize) -> Vec<(usize, usize)> {
        let mut jobs = Vec::new();
        let mut start = 0;
        while start < total {
            let end = (start + self.options.chunk_size).min(total);
            jobs.push((start, end));
            start = end;
        }
        jobs
    }

    /// Generic leader/worker scaffolding: fan `jobs` out to `workers`
    /// threads that each run `per_job`, and aggregate the result
    /// batches through a bounded channel (backpressure: workers stall
    /// rather than buffering unboundedly). Every worker thread owns one
    /// [`SchedulerWorkspace`] for its whole lifetime, so scheduling
    /// scratch buffers are allocated once per worker, not once per
    /// (job, config) — and, under the default
    /// [`HarnessOptions::fused`], each in-job sweep runs through the
    /// fused lockstep engine whose fork clones draw from the same
    /// per-worker pools.
    ///
    /// Failure semantics: a `per_job` panic is **contained**, never
    /// propagated. The panic is caught (`catch_unwind`), the job is
    /// counted in [`Metrics::jobs_failed`] with its identity and panic
    /// message recorded in [`Metrics::failed_jobs`], the worker's
    /// workspace is replaced (its scratch may be mid-update), and the
    /// worker moves on to the next job. Before this hardening, one
    /// panicking job poisoned the shared queue mutex and cascaded a
    /// panic through every worker and the `thread::scope` leader,
    /// aborting the whole sweep — intolerable for the long-lived
    /// `ptgs serve` daemon, which shares this containment policy. The
    /// queue lock itself also recovers from poisoning
    /// (`unwrap_or_else(into_inner)`): the queue's `Vec` state is valid
    /// after any panic, since `pop` is the only mutation.
    ///
    /// Cancellation semantics: once [`CoordinatorOptions::cancel`]
    /// trips, each worker keeps draining the queue but skips the work,
    /// counting every skipped job in [`Metrics::jobs_cancelled`] — the
    /// run returns promptly with the records that completed before the
    /// trip, and `jobs_done + jobs_failed + jobs_cancelled ==
    /// jobs_total` still holds.
    fn run_jobs<J, R, F>(&self, jobs: Vec<J>, per_job: F) -> (Vec<R>, Arc<Metrics>)
    where
        J: Send + std::fmt::Debug,
        R: Send,
        F: Fn(&Harness, &mut SchedulerWorkspace, &J) -> Vec<R> + Sync,
    {
        let metrics = Arc::new(Metrics::default());
        metrics.jobs_total.store(jobs.len(), Ordering::Relaxed);
        let queue = Arc::new(Mutex::new(jobs));

        let (tx, rx) = sync_channel::<Vec<R>>(self.options.channel_depth);
        let workers = self.options.workers.max(1);
        let mut records = Vec::new();
        let per_job = &per_job;

        std::thread::scope(|scope| {
            for _ in 0..workers {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let metrics = Arc::clone(&metrics);
                let cancel = self.options.cancel.clone();
                let harness = Harness {
                    schedulers: self.schedulers.clone(),
                    backend: self.backend.clone(),
                    options: self.options.harness.clone(),
                };
                scope.spawn(move || {
                    let mut ws = SchedulerWorkspace::new();
                    loop {
                        let job = {
                            queue.lock().unwrap_or_else(|e| e.into_inner()).pop()
                        };
                        let Some(job) = job else { break };
                        if cancel.is_cancelled() {
                            // Drain, don't run: every remaining job is
                            // popped and counted so the accounting
                            // invariant holds under cancellation too.
                            metrics.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                        let batch = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| per_job(&harness, &mut ws, &job)),
                        );
                        let batch = match batch {
                            Ok(batch) => batch,
                            Err(payload) => {
                                // Contained: count it, name it, drop the
                                // possibly-inconsistent scratch, move on.
                                let msg = crate::util::panic_message(payload.as_ref());
                                metrics.note_failure(format!("{job:?}: {msg}"));
                                ws = SchedulerWorkspace::new();
                                continue;
                            }
                        };
                        metrics.jobs_done.fetch_add(1, Ordering::Relaxed);
                        metrics.records.fetch_add(batch.len(), Ordering::Relaxed);
                        // Bounded send: blocks (backpressure) when the
                        // aggregator lags behind.
                        if tx.send(batch).is_err() {
                            break; // aggregator gone; shut down
                        }
                    }
                });
            }
            drop(tx); // leader's clone: aggregator ends when workers hang up

            // Leader doubles as the aggregator.
            while let Ok(batch) = rx.recv() {
                records.extend(batch);
            }
        });

        (records, metrics)
    }

    /// Run the full sweep over `specs` on the worker pool. Returns all
    /// records (sorted canonically for determinism) plus the metrics.
    pub fn run(&self, specs: &[DatasetSpec]) -> (BenchmarkResults, Arc<Metrics>) {
        let (mut records, metrics) = self.run_with(specs, run_job);
        sort_canonical(&mut records);
        (BenchmarkResults::new(records), metrics)
    }

    /// Run and return only the results.
    pub fn run_blocking(&self, specs: &[DatasetSpec]) -> BenchmarkResults {
        self.run(specs).0
    }

    /// Fan a simulation sweep out across the worker pool: every
    /// (dataset, instance) shard runs every scheduler through the
    /// execution simulator ([`crate::sim`]) for `sweep.trials` noise
    /// trials. Trace seeds derive from the instance index and trial
    /// only, so the parallel sweep produces byte-identical records to
    /// the serial [`Harness::run_all_sim`] (an integration test pins
    /// this), sorted canonically by (dataset, instance, scheduler).
    pub fn run_sim(
        &self,
        specs: &[DatasetSpec],
        sweep: &SimSweep,
    ) -> (Vec<SimRecord>, Arc<Metrics>) {
        let (mut records, metrics) =
            self.run_with(specs, |harness, ws, job| run_job_sim(harness, ws, job, sweep));
        sort_canonical(&mut records);
        (records, metrics)
    }

    /// Run the simulation sweep and return only the records.
    pub fn run_sim_blocking(&self, specs: &[DatasetSpec], sweep: &SimSweep) -> Vec<SimRecord> {
        self.run_sim(specs, sweep).0
    }

    /// Fan every scheduler out over an externally-supplied instance set
    /// (e.g. loaded workflow traces). Each instance's own name is its
    /// dataset key; records come back in canonical order and match the
    /// serial [`Harness::run_instances`] exactly.
    pub fn run_traces(
        &self,
        instances: &[crate::instance::ProblemInstance],
    ) -> (BenchmarkResults, Arc<Metrics>) {
        let jobs = self.range_jobs(instances.len());
        let (mut records, metrics) = self.run_jobs(jobs, |harness, ws, &(start, end)| {
            let mut out = Vec::with_capacity((end - start) * harness.schedulers.len());
            for i in start..end {
                let inst = &instances[i];
                // One shared SchedulingContext per instance inside
                // run_instance_ws: ranks/priorities/pins computed once
                // for the whole scheduler set, not once per config —
                // and the worker's workspace supplies every scratch
                // buffer.
                out.extend(harness.run_instance_ws(&inst.name, i, inst, ws));
            }
            out
        });
        sort_canonical(&mut records);
        (BenchmarkResults::new(records), metrics)
    }

    /// Run the trace benchmark and return only the results.
    pub fn run_traces_blocking(
        &self,
        instances: &[crate::instance::ProblemInstance],
    ) -> BenchmarkResults {
        self.run_traces(instances).0
    }

    /// Fan a simulation sweep out over an externally-supplied instance
    /// set. Byte-identical to the serial [`Harness::run_instances_sim`]
    /// (trace seeds depend on the instance index and trial only).
    pub fn run_traces_sim(
        &self,
        instances: &[crate::instance::ProblemInstance],
        sweep: &SimSweep,
    ) -> (Vec<SimRecord>, Arc<Metrics>) {
        let jobs = self.range_jobs(instances.len());
        let (mut records, metrics) = self.run_jobs(jobs, |harness, ws, &(start, end)| {
            let mut out = Vec::with_capacity((end - start) * harness.schedulers.len());
            for i in start..end {
                out.extend(harness.run_instance_sim_ws(
                    &instances[i].name,
                    i,
                    &instances[i],
                    sweep,
                    ws,
                ));
            }
            out
        });
        sort_canonical(&mut records);
        (records, metrics)
    }

    /// Run the trace simulation sweep and return only the records.
    pub fn run_traces_sim_blocking(
        &self,
        instances: &[crate::instance::ProblemInstance],
        sweep: &SimSweep,
    ) -> Vec<SimRecord> {
        self.run_traces_sim(instances, sweep).0
    }
}

/// Execute one shard: generate its instances (via their deterministic
/// per-instance streams) and run every scheduler on each, sharing one
/// [`crate::scheduler::SchedulingContext`] per instance and the
/// worker's [`SchedulerWorkspace`] across the whole shard.
fn run_job(harness: &Harness, ws: &mut SchedulerWorkspace, job: &Job) -> Vec<Record> {
    let dataset = job.spec.name();
    let mut out = Vec::with_capacity((job.end - job.start) * harness.schedulers.len());
    for i in job.start..job.end {
        let mut rng = job.spec.instance_rng(i);
        let mut inst = job.spec.generate_one(&mut rng);
        inst.name = format!("{dataset}/inst_{i:03}");
        out.extend(harness.run_instance_ws(&dataset, i, &inst, ws));
    }
    out
}

/// Execute one simulation shard: generate its instances and run every
/// scheduler through the simulator on each.
fn run_job_sim(
    harness: &Harness,
    ws: &mut SchedulerWorkspace,
    job: &Job,
    sweep: &SimSweep,
) -> Vec<SimRecord> {
    let dataset = job.spec.name();
    let mut out = Vec::with_capacity((job.end - job.start) * harness.schedulers.len());
    for i in job.start..job.end {
        let mut rng = job.spec.instance_rng(i);
        let mut inst = job.spec.generate_one(&mut rng);
        inst.name = format!("{dataset}/inst_{i:03}");
        out.extend(harness.run_instance_sim_ws(&dataset, i, &inst, sweep, ws));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::Structure;

    fn tiny_specs() -> Vec<DatasetSpec> {
        vec![
            DatasetSpec { count: 7, ..DatasetSpec::new(Structure::Chains, 1.0) },
            DatasetSpec { count: 5, ..DatasetSpec::new(Structure::InTrees, 0.2) },
        ]
    }

    #[test]
    fn parallel_equals_serial_makespans() {
        let schedulers = vec![SchedulerConfig::heft(), SchedulerConfig::mct()];
        let coord = Coordinator {
            options: CoordinatorOptions { workers: 4, chunk_size: 2, ..Default::default() },
            ..Coordinator::with_schedulers(schedulers.clone())
        };
        let (par, metrics) = coord.run(&tiny_specs());

        let serial = Harness::with_schedulers(schedulers).run_all(&tiny_specs());
        let mut serial_records = serial.records;
        sort_canonical(&mut serial_records);

        assert_eq!(par.records.len(), serial_records.len());
        for (p, s) in par.records.iter().zip(&serial_records) {
            assert_eq!(p.dataset, s.dataset);
            assert_eq!(p.instance, s.instance);
            assert_eq!(p.scheduler, s.scheduler);
            assert_eq!(p.makespan, s.makespan, "{}/{}", p.dataset, p.instance);
        }
        assert_eq!(
            metrics.jobs_done.load(Ordering::Relaxed),
            metrics.jobs_total.load(Ordering::Relaxed)
        );
        assert_eq!(metrics.records.load(Ordering::Relaxed), par.records.len());
    }

    #[test]
    fn single_worker_works() {
        let coord = Coordinator {
            options: CoordinatorOptions { workers: 1, chunk_size: 100, ..Default::default() },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::heft()])
        };
        let (res, _) = coord.run(&tiny_specs());
        assert_eq!(res.records.len(), 12);
    }

    #[test]
    fn tight_channel_backpressure_still_completes() {
        // channel_depth 1 forces workers to block on send constantly.
        let coord = Coordinator {
            options: CoordinatorOptions {
                workers: 4,
                chunk_size: 1,
                channel_depth: 1,
                ..Default::default()
            },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::met()])
        };
        let (res, _) = coord.run(&tiny_specs());
        assert_eq!(res.records.len(), 12);
    }

    #[test]
    fn parallel_sim_sweep_equals_serial() {
        let schedulers = vec![SchedulerConfig::heft(), SchedulerConfig::met()];
        let sweep = SimSweep { trials: 2, ..SimSweep::default() };
        let coord = Coordinator {
            options: CoordinatorOptions { workers: 4, chunk_size: 2, ..Default::default() },
            ..Coordinator::with_schedulers(schedulers.clone())
        };
        let par = coord.run_sim_blocking(&tiny_specs(), &sweep);

        let mut serial = Harness::with_schedulers(schedulers).run_all_sim(&tiny_specs(), &sweep);
        sort_canonical(&mut serial);
        assert_eq!(par, serial, "parallel sim sweep must match serial byte-for-byte");
    }

    #[test]
    fn parallel_traces_equal_serial() {
        let instances: Vec<_> = tiny_specs().iter().flat_map(|s| s.generate()).collect();
        let schedulers = vec![SchedulerConfig::heft(), SchedulerConfig::met()];
        let coord = Coordinator {
            options: CoordinatorOptions { workers: 4, chunk_size: 2, ..Default::default() },
            ..Coordinator::with_schedulers(schedulers.clone())
        };
        let par = coord.run_traces_blocking(&instances);
        let mut serial = Harness::with_schedulers(schedulers.clone()).run_instances(&instances);
        sort_canonical(&mut serial);
        assert_eq!(par.records.len(), serial.len());
        for (p, s) in par.records.iter().zip(&serial) {
            assert_eq!(p.dataset, s.dataset);
            assert_eq!(p.instance, s.instance);
            assert_eq!(p.scheduler, s.scheduler);
            assert_eq!(p.makespan, s.makespan, "{}/{}", p.dataset, p.instance);
        }
        // Dataset keys are the per-trace instance names, not spec names.
        assert!(par.records.iter().all(|r| r.dataset.contains("/inst_")));

        let sweep = SimSweep { trials: 2, ..SimSweep::default() };
        let par_sim = coord.run_traces_sim_blocking(&instances, &sweep);
        let mut serial_sim =
            Harness::with_schedulers(vec![SchedulerConfig::heft(), SchedulerConfig::met()])
                .run_instances_sim(&instances, &sweep);
        sort_canonical(&mut serial_sim);
        assert_eq!(par_sim, serial_sim, "trace sim sweep must match serial byte-for-byte");
    }

    #[test]
    fn panicking_job_does_not_abort_the_sweep() {
        let coord = Coordinator {
            options: CoordinatorOptions { workers: 2, chunk_size: 1, ..Default::default() },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::heft()])
        };
        // Regression: before catch_unwind + poison recovery, job 3's
        // panic poisoned the shared queue mutex and cascaded through
        // every worker and the thread::scope leader.
        let jobs: Vec<usize> = (0..6).collect();
        let (mut records, metrics) = coord.run_jobs(jobs, |_harness, _ws, &job| {
            if job == 3 {
                panic!("synthetic failure in job {job}");
            }
            vec![job]
        });
        records.sort_unstable();
        assert_eq!(records, vec![0, 1, 2, 4, 5], "surviving jobs all complete");
        assert_eq!(metrics.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(
            metrics.jobs_done.load(Ordering::Relaxed)
                + metrics.jobs_failed.load(Ordering::Relaxed),
            metrics.jobs_total.load(Ordering::Relaxed),
            "every job is accounted for"
        );
        let failed = metrics.failed_jobs.lock().unwrap();
        assert_eq!(failed.len(), 1);
        assert!(
            failed[0].contains("3") && failed[0].contains("synthetic failure in job 3"),
            "failed job identity + message surfaced: {failed:?}"
        );
    }

    #[test]
    fn cancelled_coordinator_skips_remaining_jobs() {
        // after_checks(3): the worker's first three per-job polls pass,
        // the fourth trips — so exactly 3 of 6 jobs run and the other 3
        // drain into jobs_cancelled (1 worker keeps it deterministic).
        let coord = Coordinator {
            options: CoordinatorOptions {
                workers: 1,
                chunk_size: 1,
                cancel: CancelToken::after_checks(3),
                ..Default::default()
            },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::heft()])
        };
        let jobs: Vec<usize> = (0..6).collect();
        let (records, metrics) = coord.run_jobs(jobs, |_harness, _ws, &job| vec![job]);
        assert_eq!(records.len(), 3, "three jobs completed before the trip");
        assert_eq!(metrics.jobs_done.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.jobs_cancelled.load(Ordering::Relaxed), 3);
        assert_eq!(
            metrics.jobs_done.load(Ordering::Relaxed)
                + metrics.jobs_failed.load(Ordering::Relaxed)
                + metrics.jobs_cancelled.load(Ordering::Relaxed),
            metrics.jobs_total.load(Ordering::Relaxed),
            "every job is accounted for under cancellation"
        );

        // A pre-cancelled run completes nothing.
        let coord = Coordinator {
            options: CoordinatorOptions {
                workers: 2,
                chunk_size: 1,
                cancel: CancelToken::after_checks(0),
                ..Default::default()
            },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::heft()])
        };
        let (res, metrics) = coord.run(&tiny_specs());
        assert!(res.records.is_empty());
        assert_eq!(
            metrics.jobs_cancelled.load(Ordering::Relaxed),
            metrics.jobs_total.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn run_blocking_wrapper() {
        let coord = Coordinator {
            options: CoordinatorOptions { workers: 2, chunk_size: 3, ..Default::default() },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::met()])
        };
        let res = coord.run_blocking(&tiny_specs());
        assert_eq!(res.records.len(), 12);
    }
}
