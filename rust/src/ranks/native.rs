//! Native (pure-Rust, exact f64) rank engine: single reverse/forward
//! pass over a topological order. This is the correctness oracle the
//! XLA engine is cross-checked against, and the fallback for graphs
//! exceeding the AOT-compiled padded sizes.

use super::Ranks;
use crate::graph::topological_order;
use crate::instance::ProblemInstance;

/// UpwardRank of every task:
/// `rank_u(t) = w̄(t) + max(0, max_{t'∈succ(t)} c̄(t,t') + rank_u(t'))`.
pub fn upward_rank(inst: &ProblemInstance) -> Vec<f64> {
    let order = topological_order(&inst.graph).expect("task graph must be acyclic");
    upward_rank_in(inst, &order)
}

fn upward_rank_in(inst: &ProblemInstance, order: &[usize]) -> Vec<f64> {
    let g = &inst.graph;
    // Hoist the network averages: `mean_exec`/`mean_comm` recompute
    // O(V) / O(V²) sums per call, which dominated the rank DP before
    // (EXPERIMENTS.md §Perf).
    let inv_speed = inst.network.avg_inv_speed();
    let inv_link = inst.network.avg_inv_link();
    let mut up = vec![0.0; g.len()];
    for &t in order.iter().rev() {
        let mut best = 0.0f64;
        for &(s, data) in g.successors(t) {
            best = best.max(data * inv_link + up[s]);
        }
        up[t] = g.cost(t) * inv_speed + best;
    }
    up
}

/// DownwardRank of every task:
/// `rank_d(t) = max(0, max_{t'∈pred(t)} rank_d(t') + w̄(t') + c̄(t',t))`.
pub fn downward_rank(inst: &ProblemInstance) -> Vec<f64> {
    let order = topological_order(&inst.graph).expect("task graph must be acyclic");
    downward_rank_in(inst, &order)
}

fn downward_rank_in(inst: &ProblemInstance, order: &[usize]) -> Vec<f64> {
    let g = &inst.graph;
    let inv_speed = inst.network.avg_inv_speed();
    let inv_link = inst.network.avg_inv_link();
    let mut down = vec![0.0; g.len()];
    for &t in order.iter() {
        let mut best = 0.0f64;
        for &(p, data) in g.predecessors(t) {
            best = best.max(down[p] + g.cost(p) * inv_speed + data * inv_link);
        }
        down[t] = best;
    }
    down
}

/// Both ranks in one call, sharing a single Kahn walk between the two
/// passes (the order is a pure function of the graph, so the results
/// are bit-identical to calling [`upward_rank`] and [`downward_rank`]
/// separately — at half the traversal cost, which matters on 100k-task
/// graphs).
pub fn ranks(inst: &ProblemInstance) -> Ranks {
    let order = topological_order(&inst.graph).expect("task graph must be acyclic");
    Ranks { up: upward_rank_in(inst, &order), down: downward_rank_in(inst, &order) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::network::Network;

    /// Chain a→b→c, unit costs, comm 0.5 (homogeneous speed-1 net).
    fn chain() -> ProblemInstance {
        let mut g = TaskGraph::new();
        g.add_task("a", 1.0);
        g.add_task("b", 1.0);
        g.add_task("c", 1.0);
        g.add_edge(0, 1, 0.5);
        g.add_edge(1, 2, 0.5);
        ProblemInstance::new("chain", g, Network::homogeneous(2, 1.0))
    }

    #[test]
    fn chain_ranks() {
        let inst = chain();
        let up = upward_rank(&inst);
        let down = downward_rank(&inst);
        assert_eq!(up, vec![4.0, 2.5, 1.0]);
        assert_eq!(down, vec![0.0, 1.5, 3.0]);
    }

    #[test]
    fn upward_rank_topologically_decreasing() {
        // For every edge (t, t'): rank_u(t) > rank_u(t') when costs > 0.
        let inst = chain();
        let up = upward_rank(&inst);
        for (s, d, _) in inst.graph.edges() {
            assert!(up[s] > up[d]);
        }
    }

    #[test]
    fn heterogeneous_network_uses_mean_costs() {
        let mut g = TaskGraph::new();
        g.add_task("a", 2.0);
        g.add_task("b", 2.0);
        g.add_edge(0, 1, 6.0);
        // speeds 1 and 2 → avg inv speed = 0.75; link 3 → avg inv link = 1/3
        let net = Network::new(vec![1.0, 2.0], vec![3.0, 3.0, 3.0, 3.0]);
        let inst = ProblemInstance::new("het", g, net);
        let up = upward_rank(&inst);
        // w̄ = 2·0.75 = 1.5 each; c̄ = 6/3 = 2
        assert!((up[1] - 1.5).abs() < 1e-12);
        assert!((up[0] - (1.5 + 2.0 + 1.5)).abs() < 1e-12);
    }

    #[test]
    fn disconnected_components() {
        let mut g = TaskGraph::new();
        g.add_task("a", 3.0);
        g.add_task("b", 7.0);
        let inst = ProblemInstance::new("disc", g, Network::homogeneous(1, 1.0));
        let r = ranks(&inst);
        assert_eq!(r.up, vec![3.0, 7.0]);
        assert_eq!(r.down, vec![0.0, 0.0]);
        assert_eq!(r.cp_value(), 7.0);
    }

    #[test]
    fn up_down_symmetry_on_reversed_chain() {
        // down-rank of the chain equals up-rank of the reversed chain
        // minus own execution cost.
        let inst = chain();
        let up = upward_rank(&inst);
        let down = downward_rank(&inst);
        for t in 0..3 {
            let w = inst.mean_exec(t);
            let rev_t = 2 - t;
            assert!((down[t] + w - up[rev_t]).abs() < 1e-12);
        }
    }
}
