//! Task ranks: UpwardRank (HEFT), DownwardRank, CPoP rank, and
//! critical-path extraction.
//!
//! Two engines compute the same quantities:
//!
//! * [`native`] — exact f64 dynamic programming over a topological order
//!   (works for any graph size; the correctness oracle), and
//! * [`crate::runtime::RankEngine`] — the AOT-compiled JAX/Pallas
//!   tropical-algebra kernels executed via PJRT (f32, padded sizes
//!   16/32/64, batched). [`RankBackend`] selects between them and the
//!   XLA path transparently falls back to native for oversized graphs.

pub mod native;

use std::sync::Arc;

use crate::graph::TaskId;
use crate::instance::ProblemInstance;

/// Upward and downward ranks for every task of one instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Ranks {
    /// UpwardRank: `rank_u(t) = w̄(t) + max_{t'∈succ(t)} (c̄(t,t') + rank_u(t'))`.
    pub up: Vec<f64>,
    /// DownwardRank: `rank_d(t) = max_{t'∈pred(t)} (rank_d(t') + w̄(t') + c̄(t',t))`.
    pub down: Vec<f64>,
}

impl Ranks {
    /// CPoP priority of task `t`: `rank_u(t) + rank_d(t)` — the length of
    /// the longest path through `t`.
    pub fn cpop(&self, t: TaskId) -> f64 {
        self.up[t] + self.down[t]
    }

    /// Critical-path value: `max_t cpop(t)`.
    pub fn cp_value(&self) -> f64 {
        (0..self.up.len()).map(|t| self.cpop(t)).fold(0.0, f64::max)
    }

    /// Extract *the* critical path as a source→sink chain: start from the
    /// source with maximal CPoP rank and greedily follow the successor
    /// with maximal CPoP rank (the CPoP paper's SET_CP). `rel_tol` absorbs
    /// f32 noise from the XLA engine.
    pub fn critical_path(&self, inst: &ProblemInstance, rel_tol: f64) -> Vec<TaskId> {
        let g = &inst.graph;
        if g.is_empty() {
            return Vec::new();
        }
        let cp = self.cp_value();
        let tol = rel_tol * cp.max(1.0);
        let on_cp = |t: TaskId| self.cpop(t) >= cp - tol;

        // Start: the on-CP source (there is at least one: the maximizing
        // path starts at some source). Ties → smallest id.
        let Some(mut cur) = g.sources().into_iter().find(|&t| on_cp(t)) else {
            // Degenerate (tolerance too tight); fall back to argmax task.
            let best = (0..g.len())
                .max_by(|&a, &b| self.cpop(a).partial_cmp(&self.cpop(b)).unwrap())
                .unwrap();
            return vec![best];
        };
        let mut path = vec![cur];
        loop {
            let next = g
                .successors(cur)
                .iter()
                .map(|&(s, _)| s)
                .filter(|&s| on_cp(s))
                .max_by(|&a, &b| self.cpop(a).partial_cmp(&self.cpop(b)).unwrap());
            match next {
                Some(s) => {
                    path.push(s);
                    cur = s;
                }
                None => break,
            }
        }
        path
    }
}

/// Which engine computes ranks.
#[derive(Clone, Default)]
pub enum RankBackend {
    /// Exact f64 DP over a topological order (default).
    #[default]
    Native,
    /// AOT-compiled JAX/Pallas tropical kernels via PJRT. Falls back to
    /// native for graphs larger than the biggest compiled padding.
    Xla(Arc<crate::runtime::RankEngine>),
}

impl std::fmt::Debug for RankBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RankBackend::Native => write!(f, "Native"),
            RankBackend::Xla(_) => write!(f, "Xla"),
        }
    }
}

impl RankBackend {
    /// Compute ranks for one instance.
    pub fn compute(&self, inst: &ProblemInstance) -> Ranks {
        match self {
            RankBackend::Native => native::ranks(inst),
            RankBackend::Xla(engine) => engine
                .ranks_one(inst)
                .unwrap_or_else(|| native::ranks(inst)),
        }
    }

    /// Compute only the upward rank (downward ranks zeroed) — the hot
    /// path for HEFT-style configs that never touch `down`/CPoP. The
    /// native engine skips the second DP pass entirely; the XLA artifact
    /// computes both in one fused executable anyway, so it just
    /// delegates (EXPERIMENTS.md §Perf).
    pub fn compute_upward_only(&self, inst: &ProblemInstance) -> Ranks {
        match self {
            RankBackend::Native => Ranks {
                up: native::upward_rank(inst),
                down: vec![0.0; inst.graph.len()],
            },
            RankBackend::Xla(_) => self.compute(inst),
        }
    }

    /// Relative tolerance appropriate for this backend's arithmetic:
    /// the XLA path runs in f32.
    pub fn rel_tol(&self) -> f64 {
        match self {
            RankBackend::Native => 1e-9,
            RankBackend::Xla(_) => 1e-4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::TaskGraph;
    use crate::network::Network;

    fn diamond() -> ProblemInstance {
        let mut g = TaskGraph::new();
        g.add_task("a", 1.0);
        g.add_task("b", 5.0);
        g.add_task("c", 1.0);
        g.add_task("d", 1.0);
        g.add_edge(0, 1, 1.0);
        g.add_edge(0, 2, 1.0);
        g.add_edge(1, 3, 1.0);
        g.add_edge(2, 3, 1.0);
        ProblemInstance::new("diamond", g, Network::homogeneous(2, 1.0))
    }

    #[test]
    fn critical_path_through_heavy_branch() {
        let inst = diamond();
        let r = native::ranks(&inst);
        assert_eq!(r.critical_path(&inst, 1e-9), vec![0, 1, 3]);
        assert!((r.cp_value() - 9.0).abs() < 1e-9); // 1+1+5+1+1
    }

    #[test]
    fn cpop_constant_on_cp() {
        let inst = diamond();
        let r = native::ranks(&inst);
        let cp = r.cp_value();
        for &t in &[0usize, 1, 3] {
            assert!((r.cpop(t) - cp).abs() < 1e-9);
        }
        assert!(r.cpop(2) < cp - 1e-9);
    }

    #[test]
    fn backend_default_native() {
        let b = RankBackend::default();
        assert!(matches!(b, RankBackend::Native));
        let r = b.compute(&diamond());
        assert_eq!(r.up.len(), 4);
    }
}
