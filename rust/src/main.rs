//! `ptgs` — the PTGS command-line interface.
//!
//! ```text
//! ptgs generate  --structure chains --ccr 1 --count 100 --out instances.json
//! ptgs schedule  --scheduler HEFT [--instance f.json --index 0 | --structure chains --ccr 1 --seed 0] [--backend xla]
//! ptgs benchmark [--schedulers all] [--structures all] [--ccrs all] [--count 100] [--threads N] [--repeats 1] [--fused] [--out results/benchmark.json]
//! ptgs simulate  [--schedulers all] [--structures all] [--ccrs all] [--count 20] [--sigma 0.2] [--slowdown-prob 0] [--slowdown-factor 2] [--trials 10] [--policy static|reschedule] [--slack 0.1] [--faults] [--mtbf 0.5] [--recovery 0.05] [--retries 3] [--strict] [--seed <datasets>] [--sim-seed <noise trials>] [--threads N] [--out results/robustness.csv]
//! ptgs trace     --input <file|dir[,...]> [--ccr <f64>] [--schedulers all] [--max-tasks <n>] [--nodes 4] [--heterogeneity 0.333] [--net-seed <u64>] [--no-verify] [--per-config] [--simulate (+ the simulate/fault flags)] [--strict] [--threads N] [--out <csv>]
//! ptgs analyze   [--results results/benchmark.json] [--artifact all] [--out-dir results]
//! ptgs reproduce [--count 100] [--repeats 3] [--artifact all] [--threads N] [--fused] [--simulate (+ the simulate/fault flags)] [--adversarial-corpus <dir|file[,...]>] [--out-dir results]
//! ptgs rank      [--structure chains] [--ccr 1] [--seed 0] [--backend native|xla]
//! ptgs serve     [--addr 127.0.0.1:7463] [--threads N] [--queue-depth 64] [--timeout-ms 30000] [--io-timeout-ms 30000] [--degrade-threshold 0] [--cache-size 256] [--schedulers all] [--debug]
//! ptgs adversarial [--objective pair --a MET --b HEFT | --objective max-regret] [--anneal --chains 4 --steps 64 --top 8 --temp 0.05 --cooling 0.95 --corpus-out <dir>] [--structure out_trees --ccr 1 --seed <u64>] [--generations 50] [--threads N] [--out <json>]
//! ptgs list      schedulers|datasets|artifacts
//! ```
//!
//! Worker-thread count: use `--threads N` (must be ≥ 1) or the
//! `PTGS_THREADS` environment variable; the default is available
//! parallelism. The legacy `--workers N` flag (0 = auto) is
//! **deprecated** — it is still accepted between `--threads` and
//! `PTGS_THREADS` in the resolution order, but new scripts should use
//! `--threads`. The pool serves both instance-level parallelism (the
//! coordinator) and fused-sweep fork parallelism (post-fork lockstep
//! groups drain across the same worker threads).

use ptgs::util::error::{Context, Result};
use ptgs::{anyhow, bail};
use std::path::PathBuf;
use std::sync::Arc;

use ptgs::analysis::Artifact;
use ptgs::benchmark::{BenchmarkResults, HarnessOptions};
use ptgs::coordinator::{Coordinator, CoordinatorOptions};
use ptgs::datasets::{DatasetSpec, Structure, CCRS};
use ptgs::instance::ProblemInstance;
use ptgs::ranks::RankBackend;
use ptgs::runtime::RankEngine;
use ptgs::scheduler::SchedulerConfig;
use ptgs::util::{Args, FromJson, ToJson, Value};

const USAGE: &str = "\
ptgs — Parameterized Task Graph Scheduling (Coleman et al., CS.DC 2024)

USAGE: ptgs <COMMAND> [flags]

COMMANDS:
  generate   generate dataset instances as JSON
  schedule   run one scheduler on one instance, print the schedule
  benchmark  run a scheduler sweep over datasets (parallel)
  simulate   replay schedules under perturbation; robustness table
  trace      load real workflow traces (WfCommons/simple DAG), schedule,
             optionally replay under perturbation; per-trace CSV
  analyze    derive tables/figures from saved benchmark results
  reproduce  full paper reproduction (benchmark + all 13 artifacts)
  rank       compute task ranks (native or XLA backend)
  serve      run the scheduling daemon (HTTP/1.1 JSON API, fused sweep
             per request; POST /shutdown for clean exit)
  adversarial  search for worst-case instances (greedy A/B pair, or
             --anneal simulated annealing over the fused 72-config sweep)
  list       list schedulers | datasets | artifacts

Run `ptgs <COMMAND> --help`-style flags per the module docs in
rust/src/main.rs, or see README.md.";

fn main() {
    let args = Args::from_env();
    let result = match args.positional(0) {
        Some("generate") => cmd_generate(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("benchmark") => cmd_benchmark(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("trace") => cmd_trace(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("reproduce") => cmd_reproduce(&args),
        Some("rank") => cmd_rank(&args),
        Some("serve") => cmd_serve(&args),
        Some("adversarial") => cmd_adversarial(&args),
        Some("list") => cmd_list(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let spec = spec_from_args(args, "chains")?;
    let out = PathBuf::from(args.get_or("out", "instances.json"));
    let instances = spec.generate();
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, instances.to_json().to_string())?;
    println!(
        "wrote {} instances of {} to {}",
        instances.len(),
        spec.name(),
        out.display()
    );
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let name = args.get_or("scheduler", "HEFT");
    let cfg = SchedulerConfig::from_name(&name)
        .ok_or_else(|| anyhow!("unknown scheduler {name} (try `ptgs list schedulers`)"))?;
    let inst = match args.get("instance") {
        Some(path) => {
            let index: usize = args.get_parse("index", 0).map_err(|e| anyhow!(e))?;
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            let doc: Value = ptgs::util::parse(&text).map_err(|e| anyhow!(e))?;
            let mut v = Vec::<ProblemInstance>::from_json(&doc).map_err(|e| anyhow!(e))?;
            if index >= v.len() {
                bail!("index {index} out of range ({} instances)", v.len());
            }
            v.swap_remove(index)
        }
        None => {
            let spec = spec_from_args(args, "chains")?;
            let mut rng = spec.instance_rng(0);
            spec.generate_one(&mut rng)
        }
    };
    let backend = backend_from_args(args)?;
    let lookahead: usize = args.get_parse("lookahead", 0).map_err(|e| anyhow!(e))?;
    let (s, shown_name) = if lookahead > 0 {
        let la = ptgs::scheduler::LookaheadScheduler::new(cfg, lookahead)
            .with_backend(backend);
        let name = la.name();
        (la.schedule(&inst), name)
    } else {
        (cfg.build_with(backend).schedule(&inst), cfg.name())
    };
    s.validate(&inst).map_err(|e| anyhow!("invalid schedule: {e}"))?;
    println!("scheduler: {shown_name}");
    println!("tasks: {}  nodes: {}", inst.graph.len(), inst.network.len());
    println!("makespan: {:.6}", s.makespan());
    if args.has("metrics") {
        let m = ptgs::benchmark::extended_metrics(&inst, &s);
        println!(
            "speedup: {:.4}  efficiency: {:.4}  slack: {:.4}",
            m.speedup, m.efficiency, m.slack
        );
    }
    if args.has("gantt") {
        let width = args.get_parse("width", 72usize).map_err(|e| anyhow!(e))?;
        print!("{}", ptgs::schedule::render_gantt(&inst, &s, width));
    }
    println!("{:>6} {:>6} {:>12} {:>12}  name", "task", "node", "start", "end");
    let mut rows: Vec<_> = s.assignments().collect();
    rows.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
    for a in rows {
        println!(
            "{:>6} {:>6} {:>12.4} {:>12.4}  {}",
            a.task,
            a.node,
            a.start,
            a.end,
            inst.graph.name(a.task)
        );
    }
    Ok(())
}

fn cmd_benchmark(args: &Args) -> Result<()> {
    let schedulers = parse_schedulers(&args.get_or("schedulers", "all"))?;
    let count = args.get_parse("count", 100usize).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 0x5A6A_5EEDu64).map_err(|e| anyhow!(e))?;
    let specs = parse_specs(
        &args.get_or("structures", "all"),
        &args.get_or("ccrs", "all"),
        count,
        seed,
    )?;
    let workers = worker_count(args)?;
    let repeats = args.get_parse("repeats", 1usize).map_err(|e| anyhow!(e))?;
    let out = PathBuf::from(args.get_or("out", "results/benchmark.json"));

    // Per-config timing by default: benchmark records feed the paper's
    // runtime-ratio artifacts, which need each config timed on its own.
    // `--fused` opts into the fused sweep (identical makespans, ~an
    // order of magnitude faster; runtime_ns becomes the amortized
    // fused cost, flattening runtime ratios to 1).
    let results = run_benchmark(schedulers, &specs, workers, repeats, args.has("fused"))?;
    results.save(&out)?;
    println!(
        "wrote {} records ({} schedulers × {} datasets) to {}",
        results.records.len(),
        results.schedulers().len(),
        results.datasets().len(),
        out.display()
    );
    Ok(())
}

/// Parse the shared perturbation-sweep flags (`--sigma`,
/// `--slowdown-prob`, `--slowdown-factor`, `--policy`, `--slack`,
/// `--trials`, `--sim-seed`) plus the fault-injection flags
/// (`--faults`, `--mtbf`, `--recovery`, `--retries`) used by
/// `simulate`, `trace`, and `reproduce --simulate`.
fn sweep_from_args(args: &Args) -> Result<ptgs::benchmark::SimSweep> {
    use ptgs::benchmark::SimSweep;
    use ptgs::sim::{FaultModel, Perturbation, ReplayPolicy, RetryPolicy};

    let sigma = args.get_parse("sigma", 0.2f64).map_err(|e| anyhow!(e))?;
    let slowdown_prob = args.get_parse("slowdown-prob", 0.0f64).map_err(|e| anyhow!(e))?;
    let slowdown_factor =
        args.get_parse("slowdown-factor", 2.0f64).map_err(|e| anyhow!(e))?;
    if sigma < 0.0 {
        bail!("--sigma must be >= 0, got {sigma}");
    }
    if !(0.0..=1.0).contains(&slowdown_prob) {
        bail!("--slowdown-prob must be in [0, 1], got {slowdown_prob}");
    }
    if slowdown_factor < 1.0 {
        bail!("--slowdown-factor must be >= 1, got {slowdown_factor}");
    }
    let mut perturb = Perturbation::lognormal(sigma);
    if slowdown_prob > 0.0 {
        perturb = perturb.with_slowdown(slowdown_prob, slowdown_factor);
    }
    let slack = args.get_parse("slack", 0.1f64).map_err(|e| anyhow!(e))?;
    let policy = match args.get_or("policy", "static").as_str() {
        "static" => ReplayPolicy::Static,
        "reschedule" => ReplayPolicy::Reschedule { slack },
        other => bail!("unknown policy {other} (static|reschedule)"),
    };
    let trials = args.get_parse("trials", 10usize).map_err(|e| anyhow!(e))?;

    // Fault injection: `--faults` enables the default model; naming any
    // of `--mtbf`/`--recovery` also enables it, so `ptgs simulate --mtbf
    // 0.3` does what it says without a second flag.
    let fault_flags =
        args.has("faults") || args.get("mtbf").is_some() || args.get("recovery").is_some();
    let faults = if fault_flags {
        let mtbf = args.get_parse("mtbf", 0.5f64).map_err(|e| anyhow!(e))?;
        let recovery = args.get_parse("recovery", 0.05f64).map_err(|e| anyhow!(e))?;
        if !(mtbf.is_finite() && mtbf > 0.0) {
            bail!("--mtbf must be > 0, got {mtbf}");
        }
        if !(recovery.is_finite() && recovery >= 0.0) {
            bail!("--recovery must be >= 0, got {recovery}");
        }
        FaultModel { recovery, ..FaultModel::with_mtbf(mtbf) }
    } else {
        FaultModel::none()
    };
    let max_attempts: u32 = args
        .get_parse("retries", RetryPolicy::default().max_attempts)
        .map_err(|e| anyhow!(e))?;
    if max_attempts == 0 {
        bail!("--retries must be >= 1 (1 = no retries, fail on the first kill)");
    }
    let retry = RetryPolicy { max_attempts, ..RetryPolicy::default() };

    Ok(SimSweep {
        perturb,
        policy,
        trials,
        seed: args.get_parse("sim-seed", 0x0B5E_55EDu64).map_err(|e| anyhow!(e))?,
        faults,
        retry,
    })
}

/// Print coordinator failure counts when nonzero; under `--strict` a
/// nonzero count becomes a nonzero exit.
fn report_metrics(args: &Args, metrics: &ptgs::coordinator::Metrics) -> Result<()> {
    use std::sync::atomic::Ordering;
    let failed = metrics.jobs_failed.load(Ordering::Relaxed);
    if failed == 0 {
        return Ok(());
    }
    eprintln!(
        "warning: {failed} of {} sweep job(s) failed",
        metrics.jobs_total.load(Ordering::Relaxed)
    );
    for job in metrics.failed_jobs.lock().expect("metrics mutex poisoned").iter() {
        eprintln!("  failed: {job}");
    }
    if args.has("strict") {
        bail!("{failed} sweep job(s) failed under --strict");
    }
    Ok(())
}

/// Sibling path for the fault-robustness CSV next to the main
/// robustness CSV: `<stem>_faults.csv` in the same directory.
fn fault_csv_path(out: &std::path::Path) -> PathBuf {
    let stem = out.file_stem().and_then(|s| s.to_str()).unwrap_or("robustness");
    out.with_file_name(format!("{stem}_faults.csv"))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let schedulers = parse_schedulers(&args.get_or("schedulers", "all"))?;
    let count = args.get_parse("count", 20usize).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 0x5A6A_5EEDu64).map_err(|e| anyhow!(e))?;
    let specs = parse_specs(
        &args.get_or("structures", "all"),
        &args.get_or("ccrs", "all"),
        count,
        seed,
    )?;
    let sweep = sweep_from_args(args)?;

    let options = coordinator_options(args)?;
    let coord = Coordinator { schedulers, backend: RankBackend::Native, options };
    let t0 = std::time::Instant::now();
    let (records, metrics) = coord.run_sim(&specs, &sweep);
    eprintln!(
        "simulate: {} records ({} trials each) in {:.2}s",
        records.len(),
        sweep.trials,
        t0.elapsed().as_secs_f64()
    );
    println!("{}", ptgs::analysis::robustness_table(&records));
    if !sweep.faults.is_none() {
        println!("{}", ptgs::analysis::fault_table(&records));
    }
    if let Some(out) = args.get("out") {
        let out = PathBuf::from(out);
        ptgs::analysis::write_robustness_csv(&out, &records)?;
        println!("robustness CSV written to {}", out.display());
        if !sweep.faults.is_none() {
            let fault_out = fault_csv_path(&out);
            ptgs::analysis::write_fault_csv(&fault_out, &records)?;
            println!("fault CSV written to {}", fault_out.display());
        }
    }
    report_metrics(args, &metrics)
}

/// `ptgs trace` — load real workflow traces, validate them, run every
/// configured scheduler, verify zero-noise replay exactness, and
/// (optionally) replay under perturbation into a robustness CSV.
fn cmd_trace(args: &Args) -> Result<()> {
    use ptgs::datasets::traces::{NetworkSynthesis, TraceOptions, TraceSet};
    use ptgs::sim::{Perturbation, ReplayPolicy, SimOptions};

    let input = args
        .get("input")
        .ok_or_else(|| anyhow!("--input <file|dir[,...]> is required"))?;
    let paths: Vec<PathBuf> = input.split(',').map(|s| PathBuf::from(s.trim())).collect();
    let ccr = match args.get("ccr") {
        Some(text) => {
            let c: f64 = text.parse().map_err(|e| anyhow!("invalid --ccr: {e}"))?;
            if !(c.is_finite() && c > 0.0) {
                bail!("--ccr must be > 0, got {c}");
            }
            Some(c)
        }
        None => None,
    };
    let fallback = NetworkSynthesis {
        nodes: args.get_parse("nodes", 4usize).map_err(|e| anyhow!(e))?,
        heterogeneity: args.get_parse("heterogeneity", 1.0f64 / 3.0).map_err(|e| anyhow!(e))?,
        seed: args
            .get_parse("net-seed", NetworkSynthesis::default().seed)
            .map_err(|e| anyhow!(e))?,
    };
    if fallback.nodes < 2 {
        bail!("--nodes must be >= 2, got {}", fallback.nodes);
    }
    if fallback.heterogeneity < 0.0 {
        bail!("--heterogeneity must be >= 0, got {}", fallback.heterogeneity);
    }
    let opts = TraceOptions { ccr, fallback };
    // Every instance was already validated by the loader.
    let set = TraceSet::load_paths(&paths, &opts).map_err(|e| anyhow!(e))?;
    // Corpus-size guard: scheduling is O(n·m) memory per (config,
    // instance) and the verify pre-pass is serial over all 72 configs —
    // an accidentally-huge corpus on a CI runner or shared server
    // should fail fast with a clear message, not OOM an hour in.
    if let Some(max_tasks) = args.get("max-tasks") {
        let max_tasks: usize = max_tasks
            .parse()
            .map_err(|e| anyhow!("invalid --max-tasks: {e}"))?;
        for inst in &set.instances {
            if inst.graph.len() > max_tasks {
                bail!(
                    "trace {} has {} tasks, above the --max-tasks bound of {max_tasks}; \
                     raise the bound (or drop the flag) to schedule it anyway",
                    inst.name,
                    inst.graph.len()
                );
            }
        }
    }
    for inst in &set.instances {
        println!(
            "loaded {}: {} tasks, {} edges, {} nodes, ccr {:.4}",
            inst.name,
            inst.graph.len(),
            inst.graph.num_edges(),
            inst.network.len(),
            inst.ccr()
        );
    }

    let schedulers = parse_schedulers(&args.get_or("schedulers", "all"))?;

    // Resolve (and strictly validate) the worker flags *before* the
    // serial verify pre-pass: a bad --threads/PTGS_THREADS value must
    // fail fast, not after minutes of scheduling a large corpus.
    let mut options = coordinator_options(args)?;
    options.chunk_size = 1; // traces are few and heterogeneous in size
    // Fused sweeps amortize runtime_ns equally over the configs; pass
    // --per-config when the saved records will feed runtime-ratio
    // analysis (`ptgs analyze` on a trace --out document).
    options.harness.fused = !args.has("per-config");

    // Every plan must replay bit-exactly under zero noise — the
    // simulator-consistency contract for external workloads. This
    // plans each trace once through the **fused sweep engine** (configs
    // share one lockstep loop until their decisions diverge, so the
    // pre-pass costs roughly one schedule per distinct outcome, not one
    // per config), with fork-spawned groups drained across the same
    // `--threads` pool the sweep below uses; `--no-verify` skips it for
    // large corpora. The zero-noise replay itself stays per config —
    // that is the contract under test.
    if !args.has("no-verify") {
        let mut pool: Vec<ptgs::scheduler::SchedulerWorkspace> = (0..options.workers.max(1))
            .map(|_| ptgs::scheduler::SchedulerWorkspace::new())
            .collect();
        for inst in &set.instances {
            let ctx = ptgs::scheduler::SchedulingContext::new(inst, RankBackend::Native);
            let outcome = ptgs::scheduler::fused_sweep_threaded(&ctx, &schedulers, &mut pool);
            for grp in outcome.groups {
                let plan = grp.schedule;
                plan.validate(inst).map_err(|e| {
                    anyhow!(
                        "{} on {}: invalid schedule: {e}",
                        schedulers[grp.members[0]].name(),
                        inst.name
                    )
                })?;
                for &i in &grp.members {
                    let cfg = &schedulers[i];
                    let out = ptgs::sim::simulate(
                        inst,
                        &plan,
                        cfg,
                        &SimOptions {
                            perturb: Perturbation::none(),
                            seed: 0,
                            policy: ReplayPolicy::Static,
                            ..SimOptions::default()
                        },
                    )
                    .map_err(|e| {
                        anyhow!("zero-noise replay of {} on {}: {e}", cfg.name(), inst.name)
                    })?;
                    if out.makespan != plan.makespan() {
                        bail!(
                            "zero-noise replay drifted for {} on {}: planned {} realized {}",
                            cfg.name(),
                            inst.name,
                            plan.makespan(),
                            out.makespan
                        );
                    }
                }
                pool[0].recycle(plan);
            }
        }
        println!(
            "zero-noise replay: exact for {} config(s) on {} trace(s)",
            schedulers.len(),
            set.instances.len()
        );
    }

    let coord = Coordinator { schedulers, backend: RankBackend::Native, options };

    if args.has("simulate") {
        let sweep = sweep_from_args(args)?;
        let t0 = std::time::Instant::now();
        let (records, metrics) = coord.run_traces_sim(&set.instances, &sweep);
        eprintln!(
            "trace: {} sim records ({} trials each) in {:.2}s",
            records.len(),
            sweep.trials,
            t0.elapsed().as_secs_f64()
        );
        println!("{}", ptgs::analysis::robustness_table(&records));
        if !sweep.faults.is_none() {
            println!("{}", ptgs::analysis::fault_table(&records));
        }
        let out = PathBuf::from(args.get_or("out", "results/trace_robustness.csv"));
        ptgs::analysis::write_robustness_csv(&out, &records)?;
        println!("robustness CSV written to {}", out.display());
        if !sweep.faults.is_none() {
            let fault_out = fault_csv_path(&out);
            ptgs::analysis::write_fault_csv(&fault_out, &records)?;
            println!("fault CSV written to {}", fault_out.display());
        }
        report_metrics(args, &metrics)?;
    } else {
        let results = coord.run_traces_blocking(&set.instances);
        let dedup = ptgs::analysis::dedup_rows(&results.records);
        for ds in results.datasets() {
            let recs: Vec<_> = results.records.iter().filter(|r| r.dataset == ds).collect();
            let best = recs
                .iter()
                .min_by(|a, b| a.makespan.partial_cmp(&b.makespan).unwrap())
                .expect("non-empty dataset");
            let worst = recs
                .iter()
                .max_by(|a, b| a.makespan.partial_cmp(&b.makespan).unwrap())
                .expect("non-empty dataset");
            // Distinct-schedule dedup: how many of the configs actually
            // produced different schedules on this trace (nearly free
            // under the fused sweep — hashes ride on the records).
            let distinct = dedup
                .iter()
                .find(|r| r.dataset == ds)
                .map(|r| r.distinct_schedules)
                .unwrap_or(0);
            println!(
                "{ds}: best {} ({:.4}), worst {} ({:.4}) over {} schedulers, \
                 {distinct} distinct schedule(s)",
                best.scheduler,
                best.makespan,
                worst.scheduler,
                worst.makespan,
                recs.len()
            );
        }
        if let Some(out) = args.get("out") {
            let out = PathBuf::from(out);
            results.save(&out)?;
            println!("benchmark records written to {}", out.display());
        }
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let path = PathBuf::from(args.get_or("results", "results/benchmark.json"));
    let out_dir = PathBuf::from(args.get_or("out-dir", "results"));
    let results = BenchmarkResults::load(&path)
        .with_context(|| format!("loading {}", path.display()))?;
    if results.records.iter().any(|r| r.fused_timing) {
        eprintln!(
            "warning: {} contains fused-timed records (runtime_ns amortized over the \
             whole config sweep) — runtime ratios will be flat at ~1.0; regenerate \
             with per-config timing (e.g. `ptgs trace --per-config`) for runtime-ratio \
             analysis",
            path.display()
        );
    }
    for a in parse_artifacts(&args.get_or("artifact", "all"))? {
        println!("{}", a.generate(&results, &out_dir)?);
    }
    Ok(())
}

fn cmd_reproduce(args: &Args) -> Result<()> {
    let count = args.get_parse("count", 100usize).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 0x5A6A_5EEDu64).map_err(|e| anyhow!(e))?;
    let workers = worker_count(args)?;
    let repeats = args.get_parse("repeats", 3usize).map_err(|e| anyhow!(e))?;
    let out_dir = PathBuf::from(args.get_or("out-dir", "results"));

    let specs = DatasetSpec::all(count, seed);
    let t0 = std::time::Instant::now();
    // Paper reproduction needs per-config runtime ratios, so fused
    // timing stays opt-in here too.
    let results =
        run_benchmark(SchedulerConfig::all(), &specs, workers, repeats, args.has("fused"))?;
    let elapsed = t0.elapsed().as_secs_f64();
    results.save(&out_dir.join("benchmark.json"))?;

    // `--simulate` extends the report with the perturbation/fault
    // sweep: robustness + fault-survival sections and their CSVs. The
    // shared simulate/fault flags (`--sigma`, `--trials`, `--faults`,
    // `--mtbf`, …) configure it.
    let sim_records = if args.has("simulate") {
        let sweep = sweep_from_args(args)?;
        let coord = Coordinator {
            schedulers: SchedulerConfig::all(),
            backend: RankBackend::Native,
            options: coordinator_options(args)?,
        };
        let t1 = std::time::Instant::now();
        let (records, metrics) = coord.run_sim(&specs, &sweep);
        eprintln!(
            "reproduce: {} sim records ({} trials each) in {:.2}s",
            records.len(),
            sweep.trials,
            t1.elapsed().as_secs_f64()
        );
        report_metrics(args, &metrics)?;
        records
    } else {
        Vec::new()
    };

    // `--adversarial-corpus <dir|file[,...]>` feeds a discovered corpus
    // (`ptgs adversarial --anneal --corpus-out`, or the vendored fifth
    // dataset under rust/tests/data/adversarial) into the report as a
    // per-component robustness map over worst-case shapes.
    let adversarial = match args.get("adversarial-corpus") {
        Some(list) => {
            let paths: Vec<PathBuf> = list.split(',').map(PathBuf::from).collect();
            let set = ptgs::datasets::traces::TraceSet::load_paths(
                &paths,
                &ptgs::datasets::traces::TraceOptions::default(),
            )
            .map_err(|e| anyhow!("loading adversarial corpus: {e}"))?;
            eprintln!(
                "reproduce: {} adversarial corpus instances loaded",
                set.instances.len()
            );
            set.instances
        }
        None => Vec::new(),
    };

    match args.get("artifact") {
        Some(id) => {
            for a in parse_artifacts(id)? {
                println!("{}", a.generate(&results, &out_dir)?);
            }
        }
        None => {
            let md = ptgs::analysis::write_report_full(
                &results,
                &sim_records,
                &adversarial,
                &out_dir,
                elapsed,
            )?;
            println!("{md}");
        }
    }
    println!("CSV data + REPORT.md written to {}", out_dir.display());
    Ok(())
}

fn cmd_rank(args: &Args) -> Result<()> {
    let spec = spec_from_args(args, "chains")?;
    let mut rng = spec.instance_rng(0);
    let inst = spec.generate_one(&mut rng);
    let b = backend_from_args(args)?;
    let ranks = b.compute(&inst);
    println!("backend: {b:?}  tasks: {}", inst.graph.len());
    println!("{:>6} {:>12} {:>12} {:>12}  name", "task", "up", "down", "cpop");
    for t in 0..inst.graph.len() {
        println!(
            "{t:>6} {:>12.4} {:>12.4} {:>12.4}  {}",
            ranks.up[t],
            ranks.down[t],
            ranks.cpop(t),
            inst.graph.name(t)
        );
    }
    println!("critical path: {:?}", ranks.critical_path(&inst, b.rel_tol()));
    Ok(())
}

/// `ptgs adversarial` — search for worst-case problem instances (paper
/// §V future work, ref [14]). The default is the greedy (1+λ) pairwise
/// search (`--a MET --b HEFT`); `--anneal` runs K simulated-annealing
/// chains over the fused 72-config sweep, with `--objective pair` or
/// `--objective max-regret`, and `--corpus-out <dir>` emits the top
/// discoveries as loadable trace JSONs plus their per-component
/// robustness map. One `--seed` drives both the dataset-sampled start
/// instances and the search RNG (`--search-seed` is a deprecated
/// alias); the corpus depends on `--chains` but never on `--threads`.
fn cmd_adversarial(args: &Args) -> Result<()> {
    let objective = objective_from_args(args)?;
    let mut spec = spec_from_args(args, "out_trees")?;
    let seed = adversarial_seed(args)?.unwrap_or(spec.seed);
    spec.seed = seed;

    if args.has("anneal") {
        let chains = args.get_parse("chains", 4usize).map_err(|e| anyhow!(e))?;
        if chains == 0 {
            bail!("--chains must be >= 1");
        }
        let steps = args.get_parse("steps", 64usize).map_err(|e| anyhow!(e))?;
        if steps == 0 {
            bail!("--steps must be >= 1");
        }
        let top = args.get_parse("top", 8usize).map_err(|e| anyhow!(e))?;
        if top == 0 {
            bail!("--top must be >= 1");
        }
        let opts = ptgs::analysis::AnnealOptions {
            chains,
            steps,
            top,
            temp0: args.get_parse("temp", 0.05f64).map_err(|e| anyhow!(e))?,
            cooling: args.get_parse("cooling", 0.95f64).map_err(|e| anyhow!(e))?,
            ..Default::default()
        };
        let threads = worker_count(args)?.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
        let res = ptgs::analysis::anneal_search(&objective, &spec, seed, &opts, threads)
            .map_err(|e| anyhow!(e))?;
        println!(
            "adversarial anneal: objective {} on {} (seed {seed}, {chains} chains x \
             {steps} steps, {threads} threads)",
            objective.tag(),
            spec.name(),
        );
        println!("seed instance score:     {:.4}", res.seed_score);
        println!("best discovered score:   {:.4}", res.best_score);
        for (rank, d) in res.corpus.iter().enumerate() {
            println!(
                "  #{rank:02}  score {:.4}  tasks {:3}  nodes {:2}  chain {}  hash {:016x}",
                d.score,
                d.instance.graph.len(),
                d.instance.network.len(),
                d.chain,
                d.hash,
            );
        }
        println!(
            "evaluations: {} fused sweeps, {} cache hits, {} rejected (advisory)",
            res.evaluations, res.cache_hits, res.rejected,
        );
        if let Some(dir) = args.get("corpus-out") {
            let dir = PathBuf::from(dir);
            let paths = ptgs::analysis::write_corpus(&dir, &res.corpus, &objective.tag())?;
            println!("corpus: {} trace JSONs written to {}", paths.len(), dir.display());
            let instances: Vec<ProblemInstance> =
                res.corpus.iter().map(|d| d.instance.clone()).collect();
            let rows = ptgs::analysis::component_rows(&instances).map_err(|e| anyhow!(e))?;
            println!("{}", ptgs::analysis::component_table(&rows));
        }
        return Ok(());
    }

    let ptgs::analysis::Objective::Pair { a, b } = objective else {
        bail!("--objective max-regret requires --anneal");
    };
    let opts = ptgs::analysis::AdversarialOptions {
        generations: args.get_parse("generations", 50).map_err(|e| anyhow!(e))?,
        ..Default::default()
    };
    let res =
        ptgs::analysis::adversarial_search(&a, &b, &spec, seed, &opts).map_err(|e| anyhow!(e))?;
    println!(
        "adversarial search: worst-case m({})/m({}) on {} seeds",
        a.name(),
        b.name(),
        spec.name()
    );
    println!("seed instance ratio:     {:.4}", res.seed_ratio);
    println!("adversarial ratio:       {:.4}  ({} generations)", res.ratio, res.generations);
    if args.get("out").is_some() {
        let out = PathBuf::from(args.get_or("out", "adversarial.json"));
        std::fs::write(&out, vec![res.instance.clone()].to_json().to_string())?;
        println!("instance written to {}", out.display());
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    match args.positional(1) {
        Some("schedulers") => {
            for c in SchedulerConfig::all() {
                println!("{}", c.name());
            }
        }
        Some("datasets") => {
            for s in DatasetSpec::all(100, 0) {
                println!("{}", s.name());
            }
        }
        Some("artifacts") => {
            for a in Artifact::ALL {
                println!("{:8} — {}", a.id(), a.description());
            }
        }
        other => bail!("unknown list target {other:?} (schedulers|datasets|artifacts)"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let defaults = ptgs::serve::ServeOptions::default();
    let timeout_ms: u64 = args.get_parse("timeout-ms", 30_000u64).map_err(|e| anyhow!(e))?;
    if timeout_ms == 0 {
        bail!("--timeout-ms must be >= 1");
    }
    let queue_depth: usize =
        args.get_parse("queue-depth", defaults.queue_depth).map_err(|e| anyhow!(e))?;
    if queue_depth == 0 {
        bail!("--queue-depth must be >= 1");
    }
    let io_timeout_ms: u64 = args
        .get_parse("io-timeout-ms", defaults.io_timeout.as_millis() as u64)
        .map_err(|e| anyhow!(e))?;
    if io_timeout_ms == 0 {
        bail!("--io-timeout-ms must be >= 1");
    }
    let opts = ptgs::serve::ServeOptions {
        addr: args.get_or("addr", &defaults.addr),
        workers: worker_count(args)?.unwrap_or(defaults.workers),
        queue_depth,
        default_timeout: std::time::Duration::from_millis(timeout_ms),
        cache_size: args.get_parse("cache-size", defaults.cache_size).map_err(|e| anyhow!(e))?,
        schedulers: parse_schedulers(&args.get_or("schedulers", "all"))?,
        degrade_threshold: args
            .get_parse("degrade-threshold", defaults.degrade_threshold)
            .map_err(|e| anyhow!(e))?,
        io_timeout: std::time::Duration::from_millis(io_timeout_ms),
        debug: args.has("debug"),
        ..defaults
    };
    let workers = opts.workers;
    let schedulers = opts.schedulers.len();
    let mut server = ptgs::serve::Server::start(opts)?;
    // The address line goes first and alone: scripted callers (CI, the
    // e2e test) parse it to find an ephemeral port.
    println!("ptgs serve: listening on {}", server.local_addr());
    println!(
        "ptgs serve: {workers} workers, {schedulers} schedulers; \
         POST /schedule, GET /stats, GET /healthz, POST /shutdown"
    );
    server.wait();
    println!("ptgs serve: shut down cleanly");
    Ok(())
}

// ---------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------

/// Resolve the worker-thread count: `--threads N` (strict: must be
/// ≥ 1), else the **deprecated** legacy `--workers N` (0 = auto), else
/// the `PTGS_THREADS` environment variable, else `None` (auto =
/// available parallelism). The resolved pool drives both instance-level
/// parallelism and fused-sweep fork parallelism.
fn worker_count(args: &Args) -> Result<Option<usize>> {
    if let Some(v) = args.get("threads") {
        let n: usize = v.parse().map_err(|e| anyhow!("invalid --threads: {e}"))?;
        if n == 0 {
            bail!("--threads must be >= 1, got 0 (omit the flag for auto)");
        }
        return Ok(Some(n));
    }
    if let Some(v) = args.get("workers") {
        let n: usize = v.parse().map_err(|e| anyhow!("invalid --workers: {e}"))?;
        return Ok(if n == 0 { None } else { Some(n) });
    }
    match std::env::var("PTGS_THREADS") {
        Ok(v) if !v.is_empty() => {
            let n: usize = v.parse().map_err(|e| anyhow!("invalid PTGS_THREADS: {e}"))?;
            if n == 0 {
                bail!("PTGS_THREADS must be >= 1, got 0 (unset it for auto)");
            }
            Ok(Some(n))
        }
        _ => Ok(None),
    }
}

/// Coordinator options with the resolved worker count applied.
fn coordinator_options(args: &Args) -> Result<CoordinatorOptions> {
    let mut options = CoordinatorOptions::default();
    if let Some(n) = worker_count(args)? {
        options.workers = n;
    }
    Ok(options)
}

/// Parse the adversarial objective: `--objective pair` (default, with
/// `--a`/`--b` naming the attacked and reference schedulers) or
/// `--objective max-regret` (worst config over best of all 72).
fn objective_from_args(args: &Args) -> Result<ptgs::analysis::Objective> {
    match args.get_or("objective", "pair").as_str() {
        "pair" => {
            let name_a = args.get_or("a", "MET");
            let name_b = args.get_or("b", "HEFT");
            let a = SchedulerConfig::from_name(&name_a)
                .ok_or_else(|| anyhow!("unknown scheduler {name_a}"))?;
            let b = SchedulerConfig::from_name(&name_b)
                .ok_or_else(|| anyhow!("unknown scheduler {name_b}"))?;
            Ok(ptgs::analysis::Objective::Pair { a, b })
        }
        "max-regret" | "max_regret" => Ok(ptgs::analysis::Objective::MaxRegret),
        other => bail!("unknown --objective {other} (pair|max-regret)"),
    }
}

/// The adversarial search seed: `--seed` is the primary spelling
/// (consistent with every other subcommand); the legacy
/// `--search-seed` is a **deprecated** alias (mirrors the
/// `--workers` → `--threads` precedent) that warns on stderr. One seed
/// drives both the dataset-sampled start instances and the search RNG,
/// so the two spellings are exactly equivalent.
fn adversarial_seed(args: &Args) -> Result<Option<u64>> {
    if let Some(v) = args.get("seed") {
        return Ok(Some(v.parse().map_err(|e| anyhow!("invalid --seed: {e}"))?));
    }
    if let Some(v) = args.get("search-seed") {
        eprintln!("warning: --search-seed is deprecated; use --seed");
        return Ok(Some(v.parse().map_err(|e| anyhow!("invalid --search-seed: {e}"))?));
    }
    Ok(None)
}

fn spec_from_args(args: &Args, default_structure: &str) -> Result<DatasetSpec> {
    let structure = args.get_or("structure", default_structure);
    let s = Structure::from_str_opt(&structure).ok_or_else(|| {
        anyhow!("unknown structure {structure} (in_trees|out_trees|chains|cycles|layered)")
    })?;
    Ok(DatasetSpec {
        structure: s,
        ccr: args.get_parse("ccr", 1.0f64).map_err(|e| anyhow!(e))?,
        count: args.get_parse("count", 100usize).map_err(|e| anyhow!(e))?,
        seed: args.get_parse("seed", 0x5A6A_5EEDu64).map_err(|e| anyhow!(e))?,
    })
}

fn backend_from_args(args: &Args) -> Result<RankBackend> {
    match args.get_or("backend", "native").as_str() {
        "native" => Ok(RankBackend::Native),
        "xla" => Ok(RankBackend::Xla(Arc::new(
            RankEngine::load_default().map_err(|e| anyhow!("loading artifacts: {e}"))?,
        ))),
        other => bail!("unknown backend {other} (native|xla)"),
    }
}

fn parse_schedulers(s: &str) -> Result<Vec<SchedulerConfig>> {
    if s == "all" {
        return Ok(SchedulerConfig::all());
    }
    s.split(',')
        .map(|name| {
            SchedulerConfig::from_name(name.trim())
                .ok_or_else(|| anyhow!("unknown scheduler {name}"))
        })
        .collect()
}

fn parse_specs(structures: &str, ccrs: &str, count: usize, seed: u64) -> Result<Vec<DatasetSpec>> {
    let structures: Vec<Structure> = if structures == "all" {
        Structure::ALL.to_vec()
    } else {
        structures
            .split(',')
            .map(|s| {
                Structure::from_str_opt(s.trim()).ok_or_else(|| anyhow!("unknown structure {s}"))
            })
            .collect::<Result<_>>()?
    };
    let ccrs: Vec<f64> = if ccrs == "all" {
        CCRS.to_vec()
    } else {
        ccrs.split(',')
            .map(|c| c.trim().parse::<f64>().context("bad CCR"))
            .collect::<Result<_>>()?
    };
    let mut specs = Vec::new();
    for &structure in &structures {
        for &ccr in &ccrs {
            specs.push(DatasetSpec { structure, ccr, count, seed });
        }
    }
    Ok(specs)
}

fn parse_artifacts(s: &str) -> Result<Vec<Artifact>> {
    if s == "all" {
        return Ok(Artifact::ALL.to_vec());
    }
    s.split(',')
        .map(|id| Artifact::from_id(id.trim()).ok_or_else(|| anyhow!("unknown artifact {id}")))
        .collect()
}

fn run_benchmark(
    schedulers: Vec<SchedulerConfig>,
    specs: &[DatasetSpec],
    workers: Option<usize>,
    repeats: usize,
    fused: bool,
) -> Result<BenchmarkResults> {
    let mut options = CoordinatorOptions::default();
    if let Some(n) = workers {
        options.workers = n;
    }
    options.harness = HarnessOptions { validate: true, timing_repeats: repeats.max(1), fused };
    let coord = Coordinator { schedulers, backend: RankBackend::Native, options };
    let t0 = std::time::Instant::now();
    let results = coord.run_blocking(specs);
    eprintln!(
        "benchmark: {} records in {:.2}s ({} workers)",
        results.records.len(),
        t0.elapsed().as_secs_f64(),
        coord.options.workers,
    );
    Ok(results)
}
