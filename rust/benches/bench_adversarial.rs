//! Adversarial mutant-scoring benchmark: the fused 72-config sweep
//! scorer (`analysis::score_fused`, one `fused_sweep_threaded` call
//! per mutant against warm workspaces) against the retained per-config
//! reference loop (`analysis::score_reference`, 72 isolated
//! `schedule_with` calls) — the pre-rebuild inner loop generalized
//! from 2 to 72 configs.
//!
//! Before timing anything, every mutant in the chain is asserted to
//! score **bit-identically** on both paths for both objectives (the
//! pairwise ratio and max-regret), and a warm second pass over the
//! whole chain is asserted to perform **zero** workspace buffer
//! allocations (the serve-worker O(1)-allocs discipline, via the
//! `SchedulerWorkspace::buffer_allocations()` process counter).
//!
//! Emits machine-readable `BENCH_adversarial.json` (override the path
//! with `PTGS_BENCH_OUT`) including mutants/sec for both scorers and
//! the measured `speedup_vs_pairwise`, so CI can record the search
//! throughput trajectory on every run
//! (`PTGS_BENCH_FAST=1 cargo bench --bench bench_adversarial`).

use std::hint::black_box;
use std::path::PathBuf;
use std::time::Duration;

use ptgs::analysis::{propose, score_fused, score_reference, MutationOptions, Objective};
use ptgs::benchlib::{self, Bencher, Config, Workload};
use ptgs::datasets::rng::Rng;
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::instance::ProblemInstance;
use ptgs::scheduler::{SchedulerConfig, SchedulerWorkspace};
use ptgs::util::Value;

/// A deterministic mutant chain: repeated `propose` steps from one
/// dataset-sampled seed instance — exactly the candidate stream an
/// annealing chain scores.
fn mutant_chain(n: usize) -> Vec<ProblemInstance> {
    let spec = DatasetSpec { count: 1, ..DatasetSpec::new(Structure::Cycles, 1.0) };
    let mut cur = spec.generate_one(&mut spec.instance_rng(0));
    let mut rng = Rng::seeded(0xAD7E_55);
    let opts = MutationOptions::default();
    let mut chain = Vec::with_capacity(n);
    for _ in 0..n {
        cur = propose(&cur, &mut rng, &opts);
        chain.push(cur.clone());
    }
    chain
}

fn main() {
    let n_mutants = if benchlib::fast_mode() { 8 } else { 32 };
    let mut b = Bencher::from_env().with_config(Config {
        measure_time: Duration::from_millis(200),
        samples: 10,
        warmup: Duration::from_millis(100),
    });
    let mutants = mutant_chain(n_mutants);
    let pair = Objective::Pair { a: SchedulerConfig::met(), b: SchedulerConfig::heft() };
    let mut pool = vec![SchedulerWorkspace::new()];

    // Bit-exactness gate: never publish a speedup over a baseline that
    // computes something different. Every mutant, both objectives.
    for (i, inst) in mutants.iter().enumerate() {
        for obj in [pair, Objective::MaxRegret] {
            let fused = score_fused(&obj, inst, &mut pool).expect("mutant scores");
            let reference = score_reference(&obj, inst).expect("mutant scores");
            assert_eq!(
                fused.to_bits(),
                reference.to_bits(),
                "fused score drifted from the per-config reference on mutant {i} ({obj:?}): \
                 {fused} vs {reference}"
            );
        }
    }
    println!("adversarial: fused scoring bit-identical to the per-config reference");

    // O(1)-allocs gate: the gate pass above warmed the pool across the
    // whole (size-varying) mutant chain, so a second full pass must not
    // allocate a single schedule buffer.
    let allocs_before = SchedulerWorkspace::buffer_allocations();
    for inst in &mutants {
        score_fused(&Objective::MaxRegret, inst, &mut pool).expect("mutant scores");
    }
    let warm_allocs = SchedulerWorkspace::buffer_allocations() - allocs_before;
    assert_eq!(warm_allocs, 0, "warm fused scoring must be O(1) allocations");
    println!("adversarial: warm scoring pass performed 0 buffer allocations");

    // Fused scorer: one 72-config fused sweep per mutant, warm pool.
    b.bench("adversarial/score_fused", || {
        for inst in &mutants {
            black_box(score_fused(&Objective::MaxRegret, black_box(inst), &mut pool).unwrap());
        }
    });

    // The retained reference: 72 isolated schedule_with calls per
    // mutant (shared context, no fused lockstep, no workspace reuse).
    b.bench("adversarial/score_pairwise", || {
        for inst in &mutants {
            black_box(score_reference(&Objective::MaxRegret, black_box(inst)).unwrap());
        }
    });

    let find = |name: &str| b.results.iter().find(|m| m.name == name);
    let (Some(fused_leg), Some(pairwise_leg)) =
        (find("adversarial/score_fused"), find("adversarial/score_pairwise"))
    else {
        return;
    };
    let fused_rate = mutants.len() as f64 / fused_leg.min.as_secs_f64();
    let pairwise_rate = mutants.len() as f64 / pairwise_leg.min.as_secs_f64();
    let speedup = pairwise_leg.min.as_secs_f64() / fused_leg.min.as_secs_f64();
    println!(
        "adversarial: fused scoring {fused_rate:.0} mutants/s, \
         reference {pairwise_rate:.0} mutants/s"
    );
    println!("adversarial: fused speedup vs per-config reference: {speedup:.2}x");

    let workload = Workload {
        tasks: mutants.iter().map(|i| i.graph.len()).sum(),
        edges: mutants.iter().map(|i| i.graph.num_edges()).sum(),
        nodes: mutants.iter().map(|i| i.network.len()).max().unwrap_or(0),
        workspace_capacity: pool[0].capacity(),
    };
    let mut doc = benchlib::measurements_json_with_workload(&b.results, &workload);
    if let Value::Obj(fields) = &mut doc {
        fields.push(("mutants".to_string(), Value::Num(mutants.len() as f64)));
        fields.push(("mutants_per_sec_fused".to_string(), Value::Num(fused_rate)));
        fields.push(("mutants_per_sec_pairwise".to_string(), Value::Num(pairwise_rate)));
        fields.push(("speedup_vs_pairwise".to_string(), Value::Num(speedup)));
    }
    let out = std::env::var("PTGS_BENCH_OUT")
        .unwrap_or_else(|_| "results/BENCH_adversarial.json".to_string());
    let path = PathBuf::from(out);
    benchlib::write_json(&path, &doc).expect("writing BENCH_adversarial.json");
    println!("wrote {}", path.display());
}
