//! Window-finding microbenchmarks: the inner loop of every scheduling
//! iteration (insertion-based gap scan vs append-only tail lookup).

use std::hint::black_box;

use ptgs::benchlib::Bencher;
use ptgs::graph::TaskGraph;
use ptgs::instance::ProblemInstance;
use ptgs::network::Network;
use ptgs::schedule::{Assignment, Schedule};
use ptgs::scheduler::{
    data_available_time, window_append_only, window_insertion, window_insertion_indexed,
};

/// A node timeline with `k` busy slots and small gaps between them, plus
/// one unscheduled probe task with `preds` scheduled predecessors.
fn setup(k: usize, preds: usize) -> (ProblemInstance, Schedule, usize) {
    let mut g = TaskGraph::new();
    for i in 0..(k + preds) {
        g.add_task(format!("f{i}"), 1.0);
    }
    let probe = g.add_task("probe", 1.0);
    for p in 0..preds {
        g.add_edge(k + p, probe, 1.0);
    }
    let inst = ProblemInstance::new("w", g, Network::homogeneous(2, 1.0));

    let mut s = Schedule::new(inst.graph.len(), 2);
    for i in 0..k {
        let start = i as f64 * 1.5; // 0.5-wide gaps
        s.insert(Assignment { task: i, node: 0, start, end: start + 1.0 });
    }
    for p in 0..preds {
        let start = p as f64 * 1.5;
        s.insert(Assignment { task: k + p, node: 1, start, end: start + 1.0 });
    }
    (inst, s, probe)
}

fn main() {
    let mut b = Bencher::from_env();

    for k in [4usize, 16, 64, 256] {
        let (inst, sched, probe) = setup(k, 3);
        b.bench(&format!("window/insertion_{k}"), || {
            black_box(window_insertion(&inst, &sched, probe, 0));
        });
        // The gap-indexed scan the hot path uses: binary search to the
        // first admissible gap instead of rescanning from time 0.
        let dat = data_available_time(&inst, &sched, probe, 0);
        let dur = inst.network.exec_time(inst.graph.cost(probe), 0);
        b.bench(&format!("window/insertion_indexed_{k}"), || {
            black_box(window_insertion_indexed(&sched, 0, black_box(dat), dur));
        });
        b.bench(&format!("window/append_only_{k}"), || {
            black_box(window_append_only(&inst, &sched, probe, 0));
        });
    }

    for preds in [1usize, 4, 16] {
        let (inst, sched, probe) = setup(8, preds);
        b.bench(&format!("dat/preds_{preds}"), || {
            black_box(data_available_time(&inst, &sched, probe, 0));
        });
    }
}
