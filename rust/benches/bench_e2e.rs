//! End-to-end benchmark-sweep throughput: the full coordinator pipeline
//! (generate → schedule 72 algorithms → aggregate), the workload whose
//! wall-clock regenerates every paper table/figure. Also benchmarks the
//! analysis pipeline (ratios → means → pareto) on realistic record piles.

use std::hint::black_box;
use std::time::Duration;

use ptgs::benchlib::{Bencher, Config};
use ptgs::benchmark::{BenchmarkResults, Harness};
use ptgs::coordinator::{Coordinator, CoordinatorOptions};
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::scheduler::SchedulerConfig;

fn specs(count: usize) -> Vec<DatasetSpec> {
    vec![
        DatasetSpec { count, ..DatasetSpec::new(Structure::Chains, 1.0) },
        DatasetSpec { count, ..DatasetSpec::new(Structure::InTrees, 1.0) },
    ]
}

fn main() {
    // These are heavy end-to-end runs: fewer, longer samples. (Under
    // PTGS_BENCH_FAST=1 `with_config` keeps the smoke budgets instead,
    // and the instance count shrinks.) Both the serial harness and the
    // coordinator workers share one SchedulingContext per instance —
    // the sweep-level speedup itself is tracked by bench_sweep.rs.
    let mut b = Bencher::from_env().with_config(Config {
        measure_time: Duration::from_millis(300),
        samples: 5,
        warmup: Duration::from_millis(200),
    });
    let count = if ptgs::benchlib::fast_mode() { 1 } else { 5 };

    let h = Harness::all_schedulers();
    b.bench("sweep72/serial", || {
        black_box(h.run_all(&specs(count)));
    });

    for workers in [2usize, 4, 8] {
        let coord = Coordinator {
            options: CoordinatorOptions { workers, chunk_size: 1, ..Default::default() },
            ..Coordinator::all_schedulers()
        };
        b.bench(&format!("sweep72/coordinator_{workers}w"), || {
            black_box(coord.run_blocking(&specs(count)));
        });
    }

    // Analysis pipeline on a realistic pile.
    let results = BenchmarkResults::new(
        specs(5)
            .iter()
            .flat_map(|s| Harness::all_schedulers().run_dataset(s))
            .collect(),
    );
    b.bench("analysis/ratios", || {
        black_box(results.ratios());
    });
    b.bench("analysis/mean_ratios", || {
        black_box(results.mean_ratios());
    });
    let means = results.mean_ratios();
    b.bench("analysis/pareto", || {
        black_box(ptgs::analysis::ParetoAnalysis::from_means(black_box(&means)));
    });
}
