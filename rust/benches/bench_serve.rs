//! `ptgs serve` daemon benchmark: end-to-end request latency over real
//! localhost sockets for a mix of trace sizes (the four vendored
//! workflow fixtures plus a synthetic chains instance), the cached
//! resubmission fast path, and a multi-client throughput section
//! measuring requests/sec with the daemon's own p50/p99 latency
//! counters scraped from `GET /stats`.
//!
//! Emits machine-readable `BENCH_serve.json` (override the path with
//! `PTGS_BENCH_OUT`) so CI can track serving latency and throughput on
//! every run (`PTGS_BENCH_FAST=1 cargo bench --bench bench_serve`).

use std::hint::black_box;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use ptgs::benchlib::{self, Bencher, Config};
use ptgs::datasets::traces::{load_trace, TraceOptions};
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::instance::ProblemInstance;
use ptgs::serve::{http, ServeOptions, Server};
use ptgs::util::{parse, ToJson, Value};

const FIXTURES: [&str; 4] = [
    "diamond.yaml",
    "epigenomics_like.json",
    "montage_like.json",
    "seismology_like.json",
];

fn load_fixture(name: &str) -> ProblemInstance {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/traces")
        .join(name);
    load_trace(&path, &TraceOptions::default())
        .unwrap_or_else(|e| panic!("loading {name}: {e}"))
}

/// Mixed trace sizes: every vendored fixture plus a synthetic chains
/// instance (the paper's default graph scale).
fn workloads() -> Vec<(String, String)> {
    let mut out: Vec<(String, String)> = Vec::new();
    for &name in FIXTURES {
        let short = name.trim_end_matches(".json").trim_end_matches(".yaml");
        let inst = load_fixture(name);
        let body = Value::obj(vec![("instance", inst.to_json())]).to_string();
        out.push((short.to_string(), body));
    }
    let spec = DatasetSpec { count: 1, ..DatasetSpec::new(Structure::Chains, 1.0) };
    let mut rng = spec.instance_rng(0);
    let inst = spec.generate_one(&mut rng);
    let body = Value::obj(vec![("instance", inst.to_json())]).to_string();
    out.push(("chains_synthetic".to_string(), body));
    out
}

fn main() {
    let mut b = Bencher::from_env().with_config(Config {
        measure_time: Duration::from_millis(200),
        samples: 10,
        warmup: Duration::from_millis(100),
    });
    let workloads = workloads();

    // ---- per-request end-to-end latency, cache disabled (every timed
    // iteration runs the full fused sweep on a warm worker) ----
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        cache_size: 0,
        ..ServeOptions::default()
    })
    .expect("starting uncached server");
    let addr = server.local_addr().to_string();
    for (name, body) in &workloads {
        // Warm the worker's workspace for this shape before timing.
        let (status, resp) = http::roundtrip(&addr, "POST", "/schedule", body).unwrap();
        assert_eq!(status, 200, "{name}: {resp}");
        b.bench(&format!("serve/request/{name}"), || {
            let (status, resp) =
                http::roundtrip(black_box(&addr), "POST", "/schedule", black_box(body)).unwrap();
            assert_eq!(status, 200, "{resp}");
            black_box(resp);
        });
    }
    drop(server);

    // ---- cached resubmission fast path ----
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        workers: 1,
        ..ServeOptions::default()
    })
    .expect("starting caching server");
    let addr = server.local_addr().to_string();
    let (_, body) = &workloads[0];
    let (status, resp) = http::roundtrip(&addr, "POST", "/schedule", body).unwrap();
    assert_eq!(status, 200, "{resp}");
    b.bench("serve/request_cached", || {
        let (status, resp) =
            http::roundtrip(black_box(&addr), "POST", "/schedule", black_box(body)).unwrap();
        assert_eq!(status, 200, "{resp}");
        black_box(resp);
    });
    drop(server);

    // ---- throughput: concurrent clients cycling the workload mix ----
    let client_threads = 8usize;
    let requests_per_client = if benchlib::fast_mode() { 5 } else { 40 };
    let server = Server::start(ServeOptions {
        addr: "127.0.0.1:0".into(),
        cache_size: 0,
        ..ServeOptions::default()
    })
    .expect("starting throughput server");
    let workers = ServeOptions::default().workers;
    let addr = server.local_addr().to_string();
    // Warm every worker across the shapes before the clock starts.
    for (_, body) in &workloads {
        let (status, resp) = http::roundtrip(&addr, "POST", "/schedule", body).unwrap();
        assert_eq!(status, 200, "{resp}");
    }
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..client_threads {
            let addr = &addr;
            let workloads = &workloads;
            scope.spawn(move || {
                let mut client = http::Client::connect(addr).unwrap();
                for i in 0..requests_per_client {
                    let (_, body) = &workloads[(c + i) % workloads.len()];
                    let (status, resp) = client.request("POST", "/schedule", body).unwrap();
                    assert_eq!(status, 200, "{resp}");
                }
            });
        }
    });
    let elapsed = t0.elapsed();
    let total_requests = client_threads * requests_per_client;
    let rps = total_requests as f64 / elapsed.as_secs_f64();
    let (status, stats_body) = http::roundtrip(&addr, "GET", "/stats", "").unwrap();
    assert_eq!(status, 200, "{stats_body}");
    let stats = parse(&stats_body).expect("parsing /stats");
    let latency = stats.req("latency").expect("latency block");
    let p50_us = latency.req_u64("p50_us").unwrap_or(0);
    let p99_us = latency.req_u64("p99_us").unwrap_or(0);
    let max_us = latency.req_u64("max_us").unwrap_or(0);
    println!(
        "serve: {total_requests} requests over {client_threads} clients / {workers} workers in \
         {:.2}s — {rps:.1} req/s, p50 {p50_us}us, p99 {p99_us}us",
        elapsed.as_secs_f64()
    );
    drop(server);

    // ---- machine-readable document ----
    let mut doc = benchlib::measurements_json(&b.results);
    if let Value::Obj(fields) = &mut doc {
        fields.push((
            "serve".to_string(),
            Value::obj(vec![
                ("client_threads", Value::Num(client_threads as f64)),
                ("workers", Value::Num(workers as f64)),
                ("requests", Value::Num(total_requests as f64)),
                ("wall_s", Value::Num(elapsed.as_secs_f64())),
                ("requests_per_sec", Value::Num(rps)),
                ("p50_us", Value::Num(p50_us as f64)),
                ("p99_us", Value::Num(p99_us as f64)),
                ("max_us", Value::Num(max_us as f64)),
                ("trace_mix", Value::Arr(
                    workloads.iter().map(|(n, _)| Value::Str(n.clone())).collect(),
                )),
            ]),
        ));
    }
    let out = std::env::var("PTGS_BENCH_OUT")
        .unwrap_or_else(|_| "results/BENCH_serve.json".to_string());
    let path = PathBuf::from(out);
    benchlib::write_json(&path, &doc).expect("writing BENCH_serve.json");
    println!("wrote {}", path.display());
}
