//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * **lookahead depth** (the paper's future-work component): quality
//!   vs runtime as k grows — the quality/runtime knob in action;
//! * **coordinator chunk size**: sharding granularity vs queue/channel
//!   overhead;
//! * **timing repeats**: the min-of-k runtime estimator's cost.
//!
//! The coordinator/harness paths benched here share one
//! `SchedulingContext` per instance since the zero-recompute refactor,
//! so these numbers include that amortization. `PTGS_BENCH_FAST=1`
//! shrinks budgets for CI smoke runs (see `ptgs::benchlib`).

use std::hint::black_box;

use ptgs::benchlib::Bencher;
use ptgs::benchmark::HarnessOptions;
use ptgs::coordinator::{Coordinator, CoordinatorOptions};
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::instance::ProblemInstance;
use ptgs::scheduler::{LookaheadScheduler, SchedulerConfig};

fn instances() -> Vec<ProblemInstance> {
    DatasetSpec { count: 10, ..DatasetSpec::new(Structure::OutTrees, 1.0) }.generate()
}

fn main() {
    let mut b = Bencher::from_env();

    // --- lookahead depth: runtime cost + achieved makespan ------------
    let insts = instances();
    for depth in [0usize, 1, 2] {
        let la = LookaheadScheduler::new(SchedulerConfig::heft(), depth);
        // Report the mean makespan once (quality side of the ablation).
        let mean: f64 = insts
            .iter()
            .map(|i| la.schedule(i).makespan())
            .sum::<f64>()
            / insts.len() as f64;
        println!("# lookahead depth {depth}: mean makespan {mean:.4}");
        b.bench(&format!("lookahead/depth_{depth}"), || {
            for inst in &insts {
                black_box(la.schedule(black_box(inst)));
            }
        });
    }

    // --- coordinator chunk size ----------------------------------------
    let specs =
        vec![DatasetSpec { count: 20, ..DatasetSpec::new(Structure::Chains, 1.0) }];
    for chunk in [1usize, 5, 20] {
        let coord = Coordinator {
            options: CoordinatorOptions { chunk_size: chunk, ..Default::default() },
            ..Coordinator::with_schedulers(vec![
                SchedulerConfig::heft(),
                SchedulerConfig::mct(),
            ])
        };
        b.bench(&format!("coordinator/chunk_{chunk}"), || {
            black_box(coord.run_blocking(black_box(&specs)));
        });
    }

    // --- timing repeats (runtime-ratio estimator cost) -----------------
    for repeats in [1usize, 3, 5] {
        let coord = Coordinator {
            options: CoordinatorOptions {
                harness: HarnessOptions { validate: false, timing_repeats: repeats, fused: true },
                ..Default::default()
            },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::heft()])
        };
        b.bench(&format!("timing_repeats/k_{repeats}"), || {
            black_box(coord.run_blocking(black_box(&specs)));
        });
    }

    // --- schedule validation overhead ----------------------------------
    for validate in [false, true] {
        let coord = Coordinator {
            options: CoordinatorOptions {
                harness: HarnessOptions { validate, timing_repeats: 1, fused: true },
                ..Default::default()
            },
            ..Coordinator::with_schedulers(vec![SchedulerConfig::heft()])
        };
        b.bench(&format!("validate/{validate}"), || {
            black_box(coord.run_blocking(black_box(&specs)));
        });
    }
}
