//! Large-graph scaling benchmark: the CSR + workspace scheduling core
//! over layered wide DAGs of n ∈ {1k, 10k, 50k, 100k} tasks
//! (`datasets::layered`).
//!
//! Three things are measured / checked:
//!
//! 1. **Bit-exactness gate** — at n = 1k, `schedule_into` (CSR layout,
//!    reused workspace) is asserted identical to `schedule_reference`
//!    for all 72 configs before anything is timed.
//! 2. **Time-vs-n curve** — one config per priority function (HEFT/UR,
//!    CPoP/CR, MCT/AT) through a shared context and one reused
//!    workspace per size, plus the per-call reference core on the small
//!    sizes for `speedup_vs_reference`.
//! 3. **Layout microbenchmark** — the upward-rank DP over the pre-CSR
//!    nested `Vec<Vec<(TaskId, f64)>>` adjacency vs the CSR accessors,
//!    identical arithmetic (outputs asserted equal), reported as
//!    `layout_speedup_rank_dp`.
//!
//! A 100k-task **completion pass** always runs — even under
//! `PTGS_BENCH_FAST=1` — scheduling and §I-A-validating one plan per
//! priority function and reporting tasks-scheduled/sec. Emits
//! machine-readable `BENCH_scale.json` (override the path with
//! `PTGS_BENCH_SCALE_OUT`) with the working-set proxies
//! (`benchlib::Workload`) alongside the timings.
//!
//! The **million-task streaming leg** (`scale/fused72/n1m`) drives the
//! full 72-config cube through `fused_sweep_threaded` at n = 1M in
//! full runs (a 20k *proxy* under `PTGS_BENCH_FAST=1`, flagged
//! `"proxy": true` in the JSON) and records wall-clock, the pooled
//! DAT-row high-water mark, and the peak-RSS delta next to the
//! analytic footprint the pre-streaming dense `n×m` matrices would
//! have needed. Setting `PTGS_BENCH_ASSERT_RSS=1` (CI bench-smoke
//! does) turns that comparison into a hard assertion: the streaming
//! sweep's real memory growth must stay below the dense baseline.

use std::hint::black_box;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use ptgs::benchlib::{self, Bencher, Config, Workload};
use ptgs::datasets::layered::layered_instance;
use ptgs::graph::TaskId;
use ptgs::instance::ProblemInstance;
use ptgs::ranks::RankBackend;
use ptgs::scheduler::{
    fused_sweep, fused_sweep_threaded, SchedulerConfig, SchedulerWorkspace, SchedulingContext,
};
use ptgs::util::Value;

const SEED: u64 = 0x5CA1_AB1E;
const COMPLETION_TASKS: usize = 100_000;

/// One config per priority function: the scale axis must exercise all
/// three priority pipelines (rank DP, CPoP + pins, topological).
fn per_priority_configs() -> [SchedulerConfig; 3] {
    [SchedulerConfig::heft(), SchedulerConfig::cpop(), SchedulerConfig::mct()]
}

/// The pre-CSR adjacency layout, reconstructed: per-task heap-allocated
/// successor lists. Identical contents and order to the CSR slices.
fn nested_successors(inst: &ProblemInstance) -> Vec<Vec<(TaskId, f64)>> {
    (0..inst.graph.len()).map(|t| inst.graph.successors(t).to_vec()).collect()
}

/// The upward-rank DP inner loop over a generic successor accessor —
/// the same arithmetic as `ranks::native::upward_rank`, so the nested
/// and CSR timings differ by memory layout only.
fn upward_rank_over<'a>(
    inst: &ProblemInstance,
    order: &[TaskId],
    succ: impl Fn(TaskId) -> &'a [(TaskId, f64)],
) -> Vec<f64> {
    let inv_speed = inst.network.avg_inv_speed();
    let inv_link = inst.network.avg_inv_link();
    let mut up = vec![0.0; inst.graph.len()];
    for &t in order.iter().rev() {
        let mut best = 0.0f64;
        for &(s, data) in succ(t) {
            best = best.max(data * inv_link + up[s]);
        }
        up[t] = inst.graph.cost(t) * inv_speed + best;
    }
    up
}

fn main() {
    let fast = benchlib::fast_mode();
    let configs = per_priority_configs();

    // 1. Bit-exactness gate on the small size: never publish scaling
    // numbers for a core that computes something different. Covers the
    // shared-context core *and* the fused lockstep engine.
    {
        let inst = layered_instance(SEED, 1000);
        let ctx = SchedulingContext::new(&inst, RankBackend::Native);
        let mut ws = SchedulerWorkspace::new();
        let outcome = fused_sweep(&ctx, &SchedulerConfig::ALL, &mut ws);
        let map = outcome.group_of();
        let mut pool: Vec<SchedulerWorkspace> =
            (0..2).map(|_| SchedulerWorkspace::new()).collect();
        let threaded = fused_sweep_threaded(&ctx, &SchedulerConfig::ALL, &mut pool);
        let tmap = threaded.group_of();
        for (i, cfg) in SchedulerConfig::ALL.iter().enumerate() {
            let s = cfg.build();
            let got = s.schedule_into(&ctx, &mut ws);
            let want = s.schedule_reference(&inst);
            assert_eq!(got, want, "{} drifted from the reference core at n=1000", cfg.name());
            assert_eq!(
                outcome.groups[map[i]].schedule,
                want,
                "{} fused schedule drifted at n=1000",
                cfg.name()
            );
            assert_eq!(
                threaded.groups[tmap[i]].schedule,
                want,
                "{} threaded fused schedule drifted at n=1000",
                cfg.name()
            );
            ws.recycle(got);
        }
        for grp in outcome.groups.into_iter().chain(threaded.groups) {
            ws.recycle(grp.schedule);
        }
        println!(
            "scale: all 72 configs (shared-ctx + fused + threaded) bit-identical to the reference at n=1000"
        );
    }

    let mut b = Bencher::from_env().with_config(Config {
        measure_time: Duration::from_millis(300),
        samples: 5,
        warmup: Duration::from_millis(100),
    });

    // 2. Time-vs-n curve. The reference core is only timed on the small
    // sizes (its full-timeline rescans are quadratic); the shared core
    // covers the whole curve.
    let timed_sizes: &[usize] =
        if fast { &[1000, 10_000] } else { &[1000, 10_000, 50_000, 100_000] };
    let reference_sizes: &[usize] = &[1000, 10_000];

    let mut ws = SchedulerWorkspace::new();
    for &n in timed_sizes {
        let inst = layered_instance(SEED, n);
        let ctx = SchedulingContext::new(&inst, RankBackend::Native);
        for cfg in &configs {
            ctx.warm_for(cfg);
        }
        inst.graph.freeze();
        b.bench(&format!("scale/shared_ctx/n{n}"), || {
            for cfg in &configs {
                let s = cfg.build().schedule_into(&ctx, &mut ws);
                ws.recycle(black_box(s));
            }
        });
        if reference_sizes.contains(&n) {
            b.bench(&format!("scale/reference/n{n}"), || {
                for cfg in &configs {
                    black_box(cfg.build().schedule_reference(black_box(&inst)));
                }
            });
        }

        // 3. Layout microbenchmark: nested-Vec vs CSR rank DP.
        let order = ptgs::graph::topological_order(&inst.graph).expect("layered DAGs are acyclic");
        let nested = nested_successors(&inst);
        let csr_up = upward_rank_over(&inst, &order, |t| inst.graph.successors(t));
        let nested_up = upward_rank_over(&inst, &order, |t| nested[t].as_slice());
        assert_eq!(csr_up, nested_up, "layouts must compute identical ranks at n={n}");
        b.bench(&format!("rank_dp/csr/n{n}"), || {
            black_box(upward_rank_over(&inst, &order, |t| inst.graph.successors(t)));
        });
        b.bench(&format!("rank_dp/nested/n{n}"), || {
            black_box(upward_rank_over(&inst, &order, |t| nested[t].as_slice()));
        });
    }

    // 3b. Fused 72-config sweep at scale: the whole cube through the
    // lockstep engine on wide layered DAGs. Fast mode stops at 10k
    // (the 100k × 72 sweep holds one schedule per terminal group —
    // fine in full runs, too heavy for CI smoke budgets).
    let fused_sizes: &[usize] = if fast { &[10_000] } else { &[10_000, 100_000] };
    let mut fused_stats: Vec<Value> = Vec::new();
    for &n in fused_sizes {
        let inst = layered_instance(SEED, n);
        let ctx = SchedulingContext::new(&inst, RankBackend::Native);
        for cfg in SchedulerConfig::ALL.iter() {
            ctx.warm_for(cfg);
        }
        inst.graph.freeze();
        b.bench(&format!("scale/fused72/n{n}"), || {
            let outcome = fused_sweep(black_box(&ctx), &SchedulerConfig::ALL, &mut ws);
            for grp in outcome.groups {
                ws.recycle(black_box(grp.schedule));
            }
        });
        let outcome = fused_sweep(&ctx, &SchedulerConfig::ALL, &mut ws);
        println!(
            "scale/fused72/n{n}: {} terminal groups, {} forks, {} window scans",
            outcome.stats.final_groups, outcome.stats.fork_events, outcome.stats.window_scans
        );
        fused_stats.push(Value::obj(vec![
            ("n", Value::Num(n as f64)),
            ("terminal_groups", Value::Num(outcome.stats.final_groups as f64)),
            ("fork_events", Value::Num(outcome.stats.fork_events as f64)),
            ("window_scans", Value::Num(outcome.stats.window_scans as f64)),
        ]));
        for grp in outcome.groups {
            ws.recycle(grp.schedule);
        }
    }

    // 3c. Million-task streaming leg: the full cube through the
    // threaded fused engine, one workspace per worker. A single timed
    // pass rather than a Bencher loop — at this scale the numbers that
    // matter are wall-clock, the pooled DAT-row high-water mark, and
    // peak RSS, not mean ± std over repeats. Fast mode substitutes a
    // 20k proxy so CI smoke drives the identical code path (threaded
    // forks, retirement, lazy tiles) inside its budget.
    let n1m_stats = {
        let n1m: usize = if fast { 20_000 } else { 1_000_000 };
        let inst = layered_instance(SEED, n1m);
        let ctx = SchedulingContext::new(&inst, RankBackend::Native);
        for cfg in SchedulerConfig::ALL.iter() {
            ctx.warm_for(cfg);
        }
        inst.graph.freeze();
        let threads = std::thread::available_parallelism().map_or(4, |p| p.get()).clamp(1, 8);
        let mut pool: Vec<SchedulerWorkspace> =
            (0..threads).map(|_| SchedulerWorkspace::new()).collect();

        let rss_before = benchlib::peak_rss_bytes();
        let t0 = Instant::now();
        let outcome = fused_sweep_threaded(&ctx, &SchedulerConfig::ALL, &mut pool);
        let secs = t0.elapsed().as_secs_f64();
        let rss_after = benchlib::peak_rss_bytes();

        let m = inst.network.len();
        let peak_rows =
            pool.iter().map(SchedulerWorkspace::peak_live_dat_rows).max().unwrap_or(0);
        // What the pre-streaming core would have allocated for the same
        // sweep: one dense n×m exec matrix plus a full n×m DAT matrix
        // in every terminal group's scratch (terminal groups lower-
        // bound the scratches that existed).
        let dense_baseline =
            8u64 * (n1m as u64) * (m as u64) * (outcome.stats.final_groups as u64 + 1);
        let rss_delta = match (rss_before, rss_after) {
            (Some(a), Some(b)) => Some(b.saturating_sub(a)),
            _ => None,
        };
        println!(
            "scale/fused72/n1m (n={n1m}{}): {secs:.3} s on {threads} threads, \
             {} terminal groups, {} forks, peak {} pooled DAT rows \
             (dense model: {} full n×m matrices)",
            if fast { ", proxy" } else { "" },
            outcome.stats.final_groups,
            outcome.stats.fork_events,
            peak_rows,
            outcome.stats.final_groups + 1,
        );
        if let Some(delta) = rss_delta {
            println!(
                "scale/fused72/n1m: peak-RSS delta {:.1} MiB vs dense baseline {:.1} MiB",
                delta as f64 / (1024.0 * 1024.0),
                dense_baseline as f64 / (1024.0 * 1024.0),
            );
        }
        let assert_rss =
            std::env::var("PTGS_BENCH_ASSERT_RSS").is_ok_and(|v| !v.is_empty() && v != "0");
        if assert_rss {
            let delta = rss_delta
                .expect("PTGS_BENCH_ASSERT_RSS requires procfs (VmHWM) — linux only");
            assert!(
                delta < dense_baseline,
                "streaming fused sweep at n={n1m} grew peak RSS by {delta} bytes — \
                 not below the {dense_baseline}-byte dense-matrix baseline it replaced"
            );
            println!(
                "scale/fused72/n1m: RSS assertion passed ({delta} < {dense_baseline} bytes)"
            );
        }
        // Pooled rows must track the frontier (a couple of layers of
        // the wide DAG), not the task count — the tentpole invariant,
        // asserted on every run at whatever size this leg ran.
        let layers = (n1m as f64).powf(0.4).ceil().max(2.0) as usize;
        let width = n1m / layers + 1;
        assert!(
            peak_rows <= 3 * width,
            "peak pooled DAT rows {peak_rows} exceeds 3× the layer width {width} at n={n1m}"
        );

        let mut fields = vec![
            ("n", Value::Num(n1m as f64)),
            ("proxy", Value::Bool(fast)),
            ("threads", Value::Num(threads as f64)),
            ("seconds", Value::Num(secs)),
            ("terminal_groups", Value::Num(outcome.stats.final_groups as f64)),
            ("fork_events", Value::Num(outcome.stats.fork_events as f64)),
            ("window_scans", Value::Num(outcome.stats.window_scans as f64)),
            ("peak_live_dat_rows", Value::Num(peak_rows as f64)),
            ("dense_baseline_bytes", Value::Num(dense_baseline as f64)),
        ];
        if let Some(b) = rss_after {
            fields.push(("peak_rss_bytes", Value::Num(b as f64)));
        }
        if let Some(d) = rss_delta {
            fields.push(("rss_delta_bytes", Value::Num(d as f64)));
        }
        for grp in outcome.groups {
            ws.recycle(grp.schedule);
        }
        Value::obj(fields)
    };

    // 4. 100k completion pass (all modes): one plan per priority
    // function, validated, with tasks-scheduled/sec.
    let inst = layered_instance(SEED, COMPLETION_TASKS);
    let ctx = SchedulingContext::new(&inst, RankBackend::Native);
    for cfg in &configs {
        ctx.warm_for(cfg);
    }
    inst.graph.freeze();
    let mut completion: Vec<Value> = Vec::new();
    for cfg in &configs {
        let t0 = Instant::now();
        let s = cfg.build().schedule_into(&ctx, &mut ws);
        let secs = t0.elapsed().as_secs_f64();
        s.validate(&inst)
            .unwrap_or_else(|e| panic!("{} invalid at n={COMPLETION_TASKS}: {e}", cfg.name()));
        let rate = COMPLETION_TASKS as f64 / secs;
        println!(
            "scale/complete/n{COMPLETION_TASKS} {:<10} {secs:>8.3} s  ({rate:>12.0} tasks/s)",
            cfg.name()
        );
        completion.push(Value::obj(vec![
            ("config", Value::Str(cfg.name())),
            ("priority", Value::Str(format!("{:?}", cfg.priority))),
            ("n", Value::Num(COMPLETION_TASKS as f64)),
            ("seconds", Value::Num(secs)),
            ("tasks_per_sec", Value::Num(rate)),
            ("makespan", Value::Num(s.makespan())),
        ]));
        ws.recycle(s);
    }
    // Working-set proxies from the completion pass: the document's
    // headline numbers are the 100k run, so tasks/edges/capacity must
    // all describe the same workload.
    let workload = Workload {
        tasks: inst.graph.len(),
        edges: inst.graph.num_edges(),
        nodes: inst.network.len(),
        workspace_capacity: ws.capacity(),
    };

    // Emit BENCH_scale.json: curve + layout/reference speedups at the
    // largest size both cores covered, + the completion pass.
    let find = |name: String| b.results.iter().find(|m| m.name == name);
    let mut doc = benchlib::measurements_json_with_workload(&b.results, &workload);
    if let Value::Obj(fields) = &mut doc {
        fields.push(("completion".to_string(), Value::Arr(completion)));
        fields.push(("fused".to_string(), Value::Arr(fused_stats)));
        fields.push(("n1m".to_string(), n1m_stats));
        let n_ref = *reference_sizes.last().expect("non-empty");
        if let (Some(reference), Some(shared)) = (
            find(format!("scale/reference/n{n_ref}")),
            find(format!("scale/shared_ctx/n{n_ref}")),
        ) {
            let speedup = reference.min.as_secs_f64() / shared.min.as_secs_f64();
            println!("scale: shared-ctx speedup vs reference core at n={n_ref}: {speedup:.2}x");
            fields.push(("speedup_vs_reference".to_string(), Value::Num(speedup)));
        }
        let n_top = *timed_sizes.last().expect("non-empty");
        if let (Some(nested), Some(csr)) = (
            find(format!("rank_dp/nested/n{n_top}")),
            find(format!("rank_dp/csr/n{n_top}")),
        ) {
            let speedup = nested.min.as_secs_f64() / csr.min.as_secs_f64();
            println!("scale: CSR rank-DP speedup vs nested layout at n={n_top}: {speedup:.2}x");
            fields.push(("layout_speedup_rank_dp".to_string(), Value::Num(speedup)));
        }
    }
    // Deliberately not PTGS_BENCH_OUT: that var belongs to bench_sweep,
    // and `cargo bench` runs both targets — sharing it would make this
    // bench clobber BENCH_sweep.json.
    let out = std::env::var("PTGS_BENCH_SCALE_OUT")
        .unwrap_or_else(|_| "results/BENCH_scale.json".to_string());
    let path = PathBuf::from(out);
    benchlib::write_json(&path, &doc).expect("writing BENCH_scale.json");
    println!("wrote {}", path.display());
}
