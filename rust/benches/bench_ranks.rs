//! Rank-engine benchmarks: native f64 DP vs the AOT-compiled JAX/Pallas
//! tropical kernels through PJRT, single-instance and batched.
//!
//! The XLA benches only run when `artifacts/manifest.json` exists
//! (`make artifacts`).

use std::hint::black_box;

use ptgs::benchlib::Bencher;
use ptgs::datasets::random_network;
use ptgs::datasets::rng::Rng;
use ptgs::datasets::trees::{gen_tree_with, Direction};
use ptgs::instance::ProblemInstance;
use ptgs::ranks::native;
use ptgs::runtime::RankEngine;

fn instances(count: usize, levels: usize) -> Vec<ProblemInstance> {
    (0..count)
        .map(|i| {
            let mut rng = Rng::seeded(1000 + i as u64);
            let g = gen_tree_with(&mut rng, Direction::Out, levels, 3);
            ProblemInstance::new(format!("i{i}"), g, random_network(&mut rng))
        })
        .collect()
}

fn main() {
    let mut b = Bencher::from_env();

    for levels in [3usize, 4] {
        let insts = instances(8, levels);
        let n = insts[0].graph.len();
        b.bench(&format!("ranks_native/tasks_{n}"), || {
            for inst in &insts {
                black_box(native::ranks(black_box(inst)));
            }
        });
        // Context-served ranks: first call computes, the remaining 71
        // sweep configs hit the OnceLock — the amortization the
        // zero-recompute core buys per instance.
        b.bench(&format!("ranks_ctx/amortized72_tasks_{n}"), || {
            for inst in &insts {
                let ctx = ptgs::scheduler::SchedulingContext::new(
                    black_box(inst),
                    ptgs::ranks::RankBackend::Native,
                );
                for _ in 0..72 {
                    black_box(ctx.ranks());
                }
            }
        });
    }

    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping ranks_xla: run `make artifacts` first");
        return;
    }
    let engine = RankEngine::load("artifacts").expect("artifacts load");
    for levels in [3usize, 4] {
        let insts = instances(8, levels);
        let n = insts[0].graph.len();
        // Batched: one PJRT dispatch for the whole chunk.
        b.bench(&format!("ranks_xla/batched_tasks_{n}"), || {
            black_box(engine.ranks_batch(black_box(&insts)).unwrap());
        });
        // One-at-a-time: per-dispatch overhead.
        b.bench(&format!("ranks_xla/single_tasks_{n}"), || {
            for inst in &insts {
                black_box(engine.ranks_one(black_box(inst)).unwrap());
            }
        });
    }
}
