//! Scheduler-runtime microbenchmarks — the engine behind every runtime
//! ratio in the paper (Table I, Figs. 3–10 right-hand panels).
//!
//! One group per algorithmic component axis, on a fixed reference
//! instance set, so `cargo bench` directly exposes the runtime cost of
//! each component (insertion vs append, sufferage, CP reservation,
//! priority function).

use std::hint::black_box;

use ptgs::benchlib::Bencher;
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::instance::ProblemInstance;
use ptgs::scheduler::{PriorityFn, SchedulerConfig};

fn reference_instances() -> Vec<ProblemInstance> {
    // A mix of all four structures at CCR 1, 5 instances each.
    Structure::ALL
        .iter()
        .flat_map(|&s| DatasetSpec { count: 5, ..DatasetSpec::new(s, 1.0) }.generate())
        .collect()
}

fn main() {
    let mut b = Bencher::from_env();
    let instances = reference_instances();

    // --- classics (paper Table I rows with citations) -----------------
    for (name, cfg) in [
        ("classic/HEFT", SchedulerConfig::heft()),
        ("classic/CPoP", SchedulerConfig::cpop()),
        ("classic/MCT", SchedulerConfig::mct()),
        ("classic/MET", SchedulerConfig::met()),
        ("classic/Sufferage", SchedulerConfig::sufferage_classic()),
    ] {
        let s = cfg.build();
        b.bench(name, || {
            for inst in &instances {
                black_box(s.schedule(black_box(inst)));
            }
        });
    }

    // --- zero-recompute core vs the pre-refactor reference ------------
    // Both sides pay per-call rank computation (schedule() builds a
    // private context; note it materializes the full up+down ranks
    // where the reference runs upward-only for HEFT), so this pair
    // approximates the incremental-DAT + gap-index + exec-matrix win;
    // the sweep-level rank-sharing win is measured by bench_sweep.rs.
    let heft = SchedulerConfig::heft().build();
    b.bench("core/heft_fast_path", || {
        for inst in &instances {
            black_box(heft.schedule(black_box(inst)));
        }
    });
    b.bench("core/heft_reference", || {
        for inst in &instances {
            black_box(heft.schedule_reference(black_box(inst)));
        }
    });

    // --- one component flipped at a time off HEFT ----------------------
    let base = SchedulerConfig::heft();
    for (name, cfg) in [
        ("axis/base_heft", base),
        ("axis/append_only", SchedulerConfig { append_only: true, ..base }),
        ("axis/critical_path", SchedulerConfig { critical_path: true, ..base }),
        ("axis/sufferage", SchedulerConfig { sufferage: true, ..base }),
        (
            "axis/arbitrary_topological",
            SchedulerConfig { priority: PriorityFn::ArbitraryTopological, ..base },
        ),
        (
            "axis/cpop_ranking",
            SchedulerConfig { priority: PriorityFn::CPoPRanking, ..base },
        ),
    ] {
        let s = cfg.build();
        b.bench(name, || {
            for inst in &instances {
                black_box(s.schedule(black_box(inst)));
            }
        });
    }

    // --- HEFT runtime vs graph size -----------------------------------
    use ptgs::datasets::rng::Rng;
    use ptgs::datasets::trees::{gen_tree_with, Direction};
    use ptgs::datasets::random_network;
    let s = SchedulerConfig::heft().build();
    for levels in [2usize, 3, 4, 5, 6] {
        let mut rng = Rng::seeded(levels as u64);
        let g = gen_tree_with(&mut rng, Direction::Out, levels, 3);
        let inst = ProblemInstance::new("scale", g, random_network(&mut rng));
        let name = format!("heft_scaling/tasks_{}", inst.graph.len());
        b.bench(&name, || {
            black_box(s.schedule(black_box(&inst)));
        });
    }
}
