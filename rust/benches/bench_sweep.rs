//! End-to-end 72-config sweep benchmark: the zero-recompute shared-
//! context core ([`SchedulingContext`] + incremental DAT + gap-indexed
//! timelines) against the pre-refactor per-call reference
//! (`schedule_reference`), plus the full harness record path.
//!
//! Before timing anything the two cores are asserted bit-identical on
//! every (instance, config) pair — the speedup below is only meaningful
//! because the outputs are exactly equal.
//!
//! Emits machine-readable `BENCH_sweep.json` (override the path with
//! `PTGS_BENCH_OUT`) including the measured `speedup_vs_reference`, so
//! CI can record the repo's perf trajectory on every run
//! (`PTGS_BENCH_FAST=1 cargo bench --bench bench_sweep`).

use std::hint::black_box;
use std::path::PathBuf;
use std::time::Duration;

use ptgs::benchlib::{self, Bencher, Config, Workload};
use ptgs::benchmark::Harness;
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::instance::ProblemInstance;
use ptgs::ranks::RankBackend;
use ptgs::scheduler::{SchedulerConfig, SchedulerWorkspace, SchedulingContext};
use ptgs::util::Value;

fn sweep_instances(count: usize) -> Vec<ProblemInstance> {
    [Structure::Chains, Structure::InTrees, Structure::OutTrees]
        .iter()
        .flat_map(|&s| DatasetSpec { count, ..DatasetSpec::new(s, 1.0) }.generate())
        .collect()
}

fn main() {
    let count = if benchlib::fast_mode() { 1 } else { 4 };
    let mut b = Bencher::from_env().with_config(Config {
        measure_time: Duration::from_millis(200),
        samples: 10,
        warmup: Duration::from_millis(100),
    });
    let instances = sweep_instances(count);
    let configs = SchedulerConfig::all();

    // Bit-exactness gate: never publish a speedup over a baseline that
    // computes something different.
    for inst in &instances {
        let ctx = SchedulingContext::new(inst, RankBackend::Native);
        for cfg in &configs {
            let s = cfg.build();
            assert_eq!(
                s.schedule_with(&ctx),
                s.schedule_reference(inst),
                "{} drifted from the reference core on {}",
                cfg.name(),
                inst.name
            );
        }
    }

    // The pre-refactor core: ranks, priorities, pins, DATs and timeline
    // scans re-derived inside every one of the 72 configs.
    b.bench("sweep72/reference_per_call", || {
        for inst in &instances {
            for cfg in &configs {
                black_box(cfg.build().schedule_reference(black_box(inst)));
            }
        }
    });

    // The shared-context core: one SchedulingContext per instance.
    b.bench("sweep72/shared_ctx", || {
        for inst in &instances {
            let ctx = SchedulingContext::new(inst, RankBackend::Native);
            for cfg in &configs {
                black_box(cfg.build().schedule_with(black_box(&ctx)));
            }
        }
    });

    // Shared context + one reused SchedulerWorkspace: the full
    // zero-recompute, zero-allocation sweep core.
    let mut ws = SchedulerWorkspace::new();
    b.bench("sweep72/shared_ctx_workspace", || {
        for inst in &instances {
            let ctx = SchedulingContext::new(inst, RankBackend::Native);
            for cfg in &configs {
                let s = cfg.build().schedule_into(black_box(&ctx), &mut ws);
                ws.recycle(black_box(s));
            }
        }
    });

    // The full harness path (validation + timing + records) end to end.
    let h = Harness::all_schedulers();
    b.bench("sweep72/harness_records", || {
        for (i, inst) in instances.iter().enumerate() {
            black_box(h.run_instance(&inst.name, i, inst));
        }
    });

    // Record the sweep speedup (min over samples — the stable
    // estimator) in BENCH_sweep.json for the perf trajectory. Only
    // write when both cores were actually measured, so a filtered run
    // (`cargo bench -- harness`) never clobbers a real measurement
    // file with a partial document.
    let find = |name: &str| b.results.iter().find(|m| m.name == name);
    let (Some(reference), Some(shared)) =
        (find("sweep72/reference_per_call"), find("sweep72/shared_ctx"))
    else {
        return;
    };
    let speedup = reference.min.as_secs_f64() / shared.min.as_secs_f64();
    println!("sweep72: shared-ctx speedup vs reference core: {speedup:.2}x");
    // Working-set proxies make the document comparable with
    // BENCH_scale.json and across runs of different instance budgets.
    let workload = Workload {
        tasks: instances.iter().map(|i| i.graph.len()).sum(),
        edges: instances.iter().map(|i| i.graph.num_edges()).sum(),
        nodes: instances.iter().map(|i| i.network.len()).max().unwrap_or(0),
        workspace_capacity: ws.capacity(),
    };
    let mut doc = benchlib::measurements_json_with_workload(&b.results, &workload);
    if let Value::Obj(fields) = &mut doc {
        fields.push(("speedup_vs_reference".to_string(), Value::Num(speedup)));
    }
    let out = std::env::var("PTGS_BENCH_OUT")
        .unwrap_or_else(|_| "results/BENCH_sweep.json".to_string());
    let path = PathBuf::from(out);
    benchlib::write_json(&path, &doc).expect("writing BENCH_sweep.json");
    println!("wrote {}", path.display());
}
