//! End-to-end 72-config sweep benchmark: the fused lockstep engine
//! (`fused_sweep`) against the zero-recompute shared-context core
//! ([`SchedulingContext`] + incremental DAT + gap-indexed timelines)
//! and the pre-refactor per-call reference (`schedule_reference`), plus
//! the full harness record path.
//!
//! Before timing anything the three cores are asserted bit-identical on
//! every (instance, config) pair — the speedups below are only
//! meaningful because the outputs are exactly equal.
//!
//! Emits machine-readable `BENCH_sweep.json` (override the path with
//! `PTGS_BENCH_OUT`) including the measured `speedup_vs_reference`,
//! `speedup_vs_shared_ctx` (fused vs the shared-ctx + workspace core),
//! the fused engine's shared-window-scan ratio and fork counts, so CI
//! can record the repo's perf trajectory on every run
//! (`PTGS_BENCH_FAST=1 cargo bench --bench bench_sweep`).

use std::hint::black_box;
use std::path::PathBuf;
use std::time::Duration;

use ptgs::benchlib::{self, Bencher, Config, Workload};
use ptgs::benchmark::Harness;
use ptgs::datasets::{DatasetSpec, Structure};
use ptgs::instance::ProblemInstance;
use ptgs::ranks::RankBackend;
use ptgs::scheduler::{fused, fused_sweep, SchedulerConfig, SchedulerWorkspace, SchedulingContext};
use ptgs::util::Value;

fn sweep_instances(count: usize) -> Vec<ProblemInstance> {
    [Structure::Chains, Structure::InTrees, Structure::OutTrees]
        .iter()
        .flat_map(|&s| DatasetSpec { count, ..DatasetSpec::new(s, 1.0) }.generate())
        .collect()
}

fn main() {
    let count = if benchlib::fast_mode() { 1 } else { 4 };
    let mut b = Bencher::from_env().with_config(Config {
        measure_time: Duration::from_millis(200),
        samples: 10,
        warmup: Duration::from_millis(100),
    });
    let instances = sweep_instances(count);
    let configs = SchedulerConfig::ALL;

    // Bit-exactness gate: never publish a speedup over a baseline that
    // computes something different. The fused engine is held to the
    // same standard as the shared-context core, on every instance.
    {
        let mut ws = SchedulerWorkspace::new();
        for inst in &instances {
            let ctx = SchedulingContext::new(inst, RankBackend::Native);
            let outcome = fused_sweep(&ctx, &configs, &mut ws);
            let map = outcome.group_of();
            for (i, cfg) in configs.iter().enumerate() {
                let s = cfg.build();
                let reference = s.schedule_reference(inst);
                assert_eq!(
                    s.schedule_with(&ctx),
                    reference,
                    "{} drifted from the reference core on {}",
                    cfg.name(),
                    inst.name
                );
                assert_eq!(
                    outcome.groups[map[i]].schedule,
                    reference,
                    "{} fused schedule drifted from the reference core on {}",
                    cfg.name(),
                    inst.name
                );
            }
            for grp in outcome.groups {
                ws.recycle(grp.schedule);
            }
        }
        println!("sweep72: fused + shared-ctx cores bit-identical to the reference");
    }

    // The pre-refactor core: ranks, priorities, pins, DATs and timeline
    // scans re-derived inside every one of the 72 configs.
    b.bench("sweep72/reference_per_call", || {
        for inst in &instances {
            for cfg in &configs {
                black_box(cfg.build().schedule_reference(black_box(inst)));
            }
        }
    });

    // The shared-context core: one SchedulingContext per instance.
    b.bench("sweep72/shared_ctx", || {
        for inst in &instances {
            let ctx = SchedulingContext::new(inst, RankBackend::Native);
            for cfg in &configs {
                black_box(cfg.build().schedule_with(black_box(&ctx)));
            }
        }
    });

    // Shared context + one reused SchedulerWorkspace: the full
    // zero-recompute, zero-allocation per-config sweep core.
    let mut ws = SchedulerWorkspace::new();
    b.bench("sweep72/shared_ctx_workspace", || {
        for inst in &instances {
            let ctx = SchedulingContext::new(inst, RankBackend::Native);
            for cfg in &configs {
                let s = cfg.build().schedule_into(black_box(&ctx), &mut ws);
                ws.recycle(black_box(s));
            }
        }
    });

    // The fused lockstep engine: one grouped sweep per instance, window
    // scans shared across configs until decisions diverge.
    b.bench("sweep72/fused", || {
        for inst in &instances {
            let ctx = SchedulingContext::new(inst, RankBackend::Native);
            let outcome = fused_sweep(black_box(&ctx), &configs, &mut ws);
            for grp in outcome.groups {
                ws.recycle(black_box(grp.schedule));
            }
        }
    });

    // The full harness path (validation + timing + records) end to end
    // — runs the fused engine by default.
    let h = Harness::all_schedulers();
    b.bench("sweep72/harness_records", || {
        for (i, inst) in instances.iter().enumerate() {
            black_box(h.run_instance(&inst.name, i, inst));
        }
    });

    // Sharing statistics, measured outside the timed region: per-config
    // window scans vs fused window scans, fork events, terminal groups.
    let mut per_config_scans = 0u64;
    let mut fused_scans = 0u64;
    let mut fused_forks = 0u64;
    let mut fused_groups = 0usize;
    for inst in &instances {
        let ctx = SchedulingContext::new(inst, RankBackend::Native);
        let before = fused::window_scans();
        for cfg in &configs {
            let s = cfg.build().schedule_into(&ctx, &mut ws);
            ws.recycle(s);
        }
        per_config_scans += fused::window_scans() - before;
        let outcome = fused_sweep(&ctx, &configs, &mut ws);
        fused_scans += outcome.stats.window_scans;
        fused_forks += outcome.stats.fork_events;
        fused_groups += outcome.stats.final_groups;
        for grp in outcome.groups {
            ws.recycle(grp.schedule);
        }
    }
    let scan_ratio = per_config_scans as f64 / fused_scans.max(1) as f64;
    println!(
        "sweep72: fused shared-scan ratio {scan_ratio:.2}x ({per_config_scans} per-config vs \
         {fused_scans} fused scans), {fused_forks} forks, {fused_groups} terminal groups"
    );

    // Record the sweep speedups (min over samples — the stable
    // estimator) in BENCH_sweep.json for the perf trajectory. Only
    // write when the cores were actually measured, so a filtered run
    // (`cargo bench -- harness`) never clobbers a real measurement
    // file with a partial document.
    let find = |name: &str| b.results.iter().find(|m| m.name == name);
    let (Some(reference), Some(shared), Some(shared_ws), Some(fused_leg)) = (
        find("sweep72/reference_per_call"),
        find("sweep72/shared_ctx"),
        find("sweep72/shared_ctx_workspace"),
        find("sweep72/fused"),
    ) else {
        return;
    };
    let speedup = reference.min.as_secs_f64() / shared.min.as_secs_f64();
    let fused_speedup = shared_ws.min.as_secs_f64() / fused_leg.min.as_secs_f64();
    println!("sweep72: shared-ctx speedup vs reference core: {speedup:.2}x");
    println!("sweep72: fused speedup vs shared-ctx+workspace core: {fused_speedup:.2}x");
    // Working-set proxies make the document comparable with
    // BENCH_scale.json and across runs of different instance budgets.
    let workload = Workload {
        tasks: instances.iter().map(|i| i.graph.len()).sum(),
        edges: instances.iter().map(|i| i.graph.num_edges()).sum(),
        nodes: instances.iter().map(|i| i.network.len()).max().unwrap_or(0),
        workspace_capacity: ws.capacity(),
    };
    let mut doc = benchlib::measurements_json_with_workload(&b.results, &workload);
    if let Value::Obj(fields) = &mut doc {
        fields.push(("speedup_vs_reference".to_string(), Value::Num(speedup)));
        fields.push(("speedup_vs_shared_ctx".to_string(), Value::Num(fused_speedup)));
        fields.push((
            "fused".to_string(),
            Value::obj(vec![
                ("shared_scan_ratio", Value::Num(scan_ratio)),
                ("window_scans", Value::Num(fused_scans as f64)),
                ("per_config_window_scans", Value::Num(per_config_scans as f64)),
                ("fork_events", Value::Num(fused_forks as f64)),
                ("terminal_groups", Value::Num(fused_groups as f64)),
                ("instances", Value::Num(instances.len() as f64)),
            ]),
        ));
    }
    let out = std::env::var("PTGS_BENCH_OUT")
        .unwrap_or_else(|_| "results/BENCH_sweep.json".to_string());
    let path = PathBuf::from(out);
    benchlib::write_json(&path, &doc).expect("writing BENCH_sweep.json");
    println!("wrote {}", path.display());
}
